"""Cross-cluster scheduling playground: replay a Table-I-style trace through
the discrete-event simulator under ANY registered policy and print the
Fig.7-style metrics.

  PYTHONPATH=src python examples/cross_cluster_sim.py --policy maestro \
      --rate 2.0 --batch-ratio 0.8 --jobs 400
"""
import argparse

import numpy as np

from repro.core.predictor import MaestroPred, PredictorConfig
from repro.core.predictor.gbdt import GBDTConfig
from repro.core.sched.policies import (POLICIES, make_policy,
                                       registered_policies)
from repro.data.tracegen import generate_trace, stratified_temporal_split
from repro.sim.simulator import SimConfig, Simulator


def train_predictor(n_jobs=400):
    jobs = generate_trace(n_jobs, seed=5)
    train, _ = stratified_temporal_split(jobs)
    cfg = PredictorConfig(
        cls=GBDTConfig(objective="logloss", n_trees=30, max_leaves=7),
        reg=GBDTConfig(n_trees=40, max_leaves=15))
    return MaestroPred(cfg).fit(
        [s.obs for s in train],
        np.array([s.true_len for s in train], float),
        np.array([float(s.tool_call) for s in train]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="maestro",
                    choices=list(registered_policies()) + ["all"])
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--batch-ratio", type=float, default=0.8)
    ap.add_argument("--jobs", type=int, default=400)
    args = ap.parse_args()

    names = (list(registered_policies()) if args.policy == "all"
             else [args.policy])
    mp = None
    if any(POLICIES[n].needs_predictor for n in names):
        print("[sim] training predictor ...")
        mp = train_predictor()
    print(f"[sim] {args.jobs} jobs @ {args.rate}/s, "
          f"batch ratio {args.batch_ratio}")
    for name in names:
        jobs = generate_trace(args.jobs, rate=args.rate,
                              batch_ratio=args.batch_ratio, seed=13)
        r = Simulator(jobs, make_policy(name, predictor=mp),
                      SimConfig()).run()
        print(f"  {r.policy:12s} slo={r.slo_attainment:5.1%} "
              f"mean_lat={r.mean_latency_s:7.1f}s "
              f"interactive_queue={r.interactive_queue_delay_s:6.2f}s "
              f"cold_starts={r.cold_starts}")


if __name__ == "__main__":
    main()
