"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on synthetic token data with the full production loop —
AdamW, microbatching, checkpoint/restart (kill-and-resume), and straggler
detection hooks.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.distributed.fault import StragglerDetector
from repro.models import build_model
from repro.training import OptConfig, adamw_init, make_train_step


def synthetic_batch(key, vocab, batch, seq):
    """Markov-ish synthetic LM data: next token = (3x + 7) % vocab + noise."""
    base = jax.random.randint(key, (batch, 1), 0, vocab)
    steps = jnp.arange(seq)[None, :]
    toks = (base * 3 + 7 * steps) % vocab
    noise = jax.random.bernoulli(key, 0.05, toks.shape)
    rand = jax.random.randint(key, toks.shape, 0, vocab)
    toks = jnp.where(noise, rand, toks).astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=129)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--n-micro", type=int, default=2)
    args = ap.parse_args()

    # ~100M-class config: qwen3 family, scaled down
    cfg = dataclasses.replace(
        get_config("qwen3-8b"), n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab=2048,
        dtype=jnp.float32, name="qwen3-100m")
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, microbatch x{args.n_micro}")

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20)
    opt = adamw_init(params)
    start = 0
    if latest_step(args.ckpt) is not None:   # fault-tolerant restart
        (params, opt), extra = restore(args.ckpt, (params, opt))
        start = extra["step"]
        print(f"[train] resumed from checkpoint at step {start}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, n_micro=args.n_micro))
    sd = StragglerDetector()
    t_start = time.time()
    for step in range(start, args.steps):
        k = jax.random.fold_in(key, step)
        batch = synthetic_batch(k, cfg.vocab, args.batch, args.seq)
        t0 = time.time()
        params, opt, m = step_fn(params, opt, batch)
        sd.observe(0, time.time() - t0)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"  step {step:4d}: loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.5f} "
                  f"({(time.time()-t0)*1e3:.0f}ms/step)")
        if step and step % 100 == 0:
            save(args.ckpt, (params, opt), step=step,
                 extra={"step": step}, async_=True)
    save(args.ckpt, (params, opt), step=args.steps,
         extra={"step": args.steps})
    tput = args.batch * (args.seq - 1) * (args.steps - start) \
        / (time.time() - t_start)
    print(f"[train] done: final loss {float(m['loss']):.4f}, "
          f"{tput:.0f} tok/s on CPU; checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
