"""Quickstart: build any of the 10 assigned architectures, run a forward /
train step, then serve a few requests through the continuous-batching engine
with Maestro's memory accounting.

  PYTHONPATH=src python examples/quickstart.py --arch qwen3-8b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.core.runtime.accounting import MemoryAccountant
from repro.models import build_model
from repro.serving.engine import Engine, Request
from repro.training import OptConfig, adamw_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_configs())
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"[quickstart] {cfg.name}: {cfg.param_count()/1e9:.1f}B params "
          f"({cfg.family}); running the REDUCED smoke config on CPU")
    cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[quickstart] reduced model: {n/1e6:.1f}M params")

    # --- a few train steps -------------------------------------------------
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab)
    extras = {}
    if cfg.encoder is not None:
        extras["frames"] = jax.random.normal(
            key, (4, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)
    if cfg.cross_attn is not None and cfg.family == "vlm":
        extras["ctx_embeds"] = jax.random.normal(
            key, (4, cfg.cross_attn.n_ctx_tokens, cfg.d_model), cfg.dtype)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1), **extras}
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, warmup_steps=1)))
    opt = adamw_init(params)
    for i in range(args.steps):
        params, opt, m = step(params, opt, batch)
        print(f"  step {i}: loss={float(m['loss']):.4f} "
              f"gnorm={float(m['grad_norm']):.3f}")

    # --- serve through the engine ------------------------------------------
    acc = MemoryAccountant(m_total=256e6)
    eng = Engine(model, params, acc, max_slots=2, s_max=96)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(req_id=i, extras=extras and {
            k: v[:1] for k, v in extras.items()},
            tokens=list(rng.integers(0, cfg.vocab, 12)), max_new=8))
    done = eng.drain()
    for r in done:
        print(f"  request {r.req_id}: generated {r.out}")
    print(f"[quickstart] OK — KV accountant headroom "
          f"{acc.headroom/1e6:.0f}MB, invariant={acc.check_invariant()}")


if __name__ == "__main__":
    main()
