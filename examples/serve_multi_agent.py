"""Serve a multi-agent workload LIVE through the cluster gateway.

Thin driver over ``repro.serving.gateway``: train the agent-aware cost
predictor on a recorded trace, build a real-engine fleet across simulated-RTT
clusters, convert a generated workflow trace into live jobs, and serve them
end-to-end through the full Maestro hierarchy (SRTF queue -> fitness routing
-> rho-margin admission -> node engines -> calibration feedback).

  PYTHONPATH=src python examples/serve_multi_agent.py            # in-process
  PYTHONPATH=src python examples/serve_multi_agent.py process    # one worker
                                                                 # per node
  PYTHONPATH=src python examples/serve_multi_agent.py socket     # workers over
                                                                 # framed TCP
"""
import time

import numpy as np

from repro.core.predictor import MaestroPred, PredictorConfig
from repro.core.predictor.gbdt import GBDTConfig
from repro.data.tracegen import generate_trace, stratified_temporal_split
from repro.serving.cluster import (ClusterSpec, build_fleet, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet


def train_predictor(train_jobs: int = 300, seed: int = 9) -> MaestroPred:
    jobs = generate_trace(train_jobs, seed=seed)
    train, _ = stratified_temporal_split(jobs)
    cfg = PredictorConfig(
        cls=GBDTConfig(objective="logloss", n_trees=30, max_leaves=7),
        reg=GBDTConfig(n_trees=40, max_leaves=15))
    return MaestroPred(cfg).fit(
        [s.obs for s in train],
        np.array([s.true_len for s in train], float),
        np.array([float(s.tool_call) for s in train]))


def main(n_jobs: int = 6, train_jobs: int = 300, policy: str = "maestro",
         seed: int = 7, backend: str = "inproc"):
    """``policy`` is any name from the unified registry
    (``repro.core.sched.policies``): the same objects drive the trace
    simulator and this live gateway. ``backend`` picks the node runtime
    mode — "inproc" steps every node cooperatively in this process
    (deterministic default), "process" spawns one worker process per node
    so the fleet genuinely runs concurrently, "socket" runs the same
    workers over the framed-TCP transport (localhost here; the remote-host
    path is ``python -m repro.serving.worker --listen``)."""
    print(f"[serve] training the agent-aware cost predictor "
          f"({train_jobs} recorded jobs) ...")
    pred = train_predictor(train_jobs)

    spec = ClusterSpec()     # 3 real nodes over 2 clusters, 3-model zoo
    print(f"[serve] building {len(spec.nodes)} {backend} nodes over "
          f"{spec.n_clusters} clusters, zoo={list(spec.model_names)} ...")
    fleet = build_fleet(spec, backend=backend)

    trace = generate_trace(n_jobs, rate=1.5, seed=seed)
    jobs = jobs_from_trace(trace, n_clusters=spec.rtt_s.shape[0], seed=seed)
    n_stages = sum(len(j.stages) for j in jobs)
    print(f"[serve] serving {len(jobs)} jobs / {n_stages} stages "
          f"under the '{policy}' policy ...")

    t0 = time.time()
    try:
        gw = ClusterGateway(fleet, spec.rtt_s, predictor=pred, policy=policy,
                            cfg=GatewayConfig(node_backend=backend))
        m = gw.run(jobs)
        print(f"[serve] done in {time.time() - t0:.1f}s wall "
              f"({gw.tick} ticks = {gw.now:.1f}s virtual)")
        if backend != "inproc":
            wire = (f", {m.rpc_bytes_sent + m.rpc_bytes_recv} B on the wire"
                    if backend == "socket" else "")
            print(f"[serve]   worker IPC           : {m.ipc_calls} round "
                  f"trips ({m.ipc_wall_s:.1f}s), engine step wall "
                  f"{m.worker_step_wall_s:.1f}s{wire}")
        print(f"[serve]   finished jobs        : {m.finished_jobs}/"
              f"{len(jobs)} (dropped {m.dropped_jobs})")
        print(f"[serve]   SLO attainment       : {m.slo_attainment:.2f}")
        print(f"[serve]   mean / p95 latency   : {m.mean_latency_s:.2f}s / "
              f"{m.p95_latency_s:.2f}s")
        print(f"[serve]   interactive q-delay  : "
              f"{m.interactive_queue_delay_s:.2f}s")
        print(f"[serve]   cold starts / preempt: {m.cold_starts} / "
              f"{m.preemptions}")
        print(f"[serve]   generated tokens     : {m.generated_tokens}")
        if gw.ctl is not None:
            print(f"[serve]   calibrated rho       : {gw.ctl.rho.rho:.3f}")
        for nid, node in gw.fleet.items():
            sig = node.signal()
            print(f"[serve] node {nid} (cluster {node.cluster_id}): "
                  f"warm={sorted(sig.warm_models)} "
                  f"headroom={sig.headroom / 1e6:.0f}MB")
    finally:
        # handles, not the gateway: covers constructor failures too
        close_fleet(fleet)
    return m


if __name__ == "__main__":
    import sys
    main(backend=sys.argv[1] if len(sys.argv) > 1 else "inproc")
