"""End-to-end driver: serve a multi-agent workflow through the full Maestro
pipeline — agent-context observation -> cost prediction -> fitness routing ->
node runtimes with real colocated (tiny) models -> post-execution calibration.

Two nodes with different HBM budgets colocate three models; a Travel-
Assistant-style workflow of dependent stages is scheduled through
MaestroController and executed for real on CPU.

  PYTHONPATH=src python examples/serve_multi_agent.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.control_loop import MaestroController
from repro.core.predictor import (MaestroPred, PredictorConfig,
                                  StageObservation)
from repro.core.predictor.gbdt import GBDTConfig
from repro.core.predictor.cost_model import HardwareSpec, ModelProfile
from repro.data.tracegen import generate_trace, stratified_temporal_split
from repro.models import build_model
from repro.serving.engine import Request
from repro.serving.node_runtime import NodeRuntime

RTT = np.array([[0.001, 0.05], [0.05, 0.001]])


def main():
    # 1) train the cost predictor on a recorded trace (dispatch gateway)
    print("[serve] training the agent-aware cost predictor ...")
    jobs = generate_trace(300, seed=9)
    train, _ = stratified_temporal_split(jobs)
    pred = MaestroPred(PredictorConfig(
        cls=GBDTConfig(objective="logloss", n_trees=30, max_leaves=7),
        reg=GBDTConfig(n_trees=40, max_leaves=15))).fit(
        [s.obs for s in train],
        np.array([s.true_len for s in train], float),
        np.array([float(s.tool_call) for s in train]))

    # 2) two nodes colocating tiny real models
    zoo, host = {}, {}
    for name in ("qwen3-8b", "starcoder2-15b", "mamba2-2.7b"):
        cfg = get_config(name).reduced()
        m = build_model(cfg)
        zoo[name] = m
        host[name] = jax.tree.map(np.asarray,
                                  m.init(jax.random.PRNGKey(1)))
    nodes = [NodeRuntime(0, 0, zoo, host, hbm_budget=1.2e9, s_max=64),
             NodeRuntime(1, 1, zoo, host, hbm_budget=0.6e9, s_max=64)]

    profiles = {n.profiles[k].name: n.profiles[k]
                for n in nodes[:1] for k in n.profiles}
    ctl = MaestroController(pred, profiles, RTT)

    # 3) a dependent multi-agent workflow (planner -> tool -> writer -> chat)
    workflow = [
        ("qwen3-8b", "planner", 0, False),
        ("mamba2-2.7b", "tool_agent", 3, False),
        ("starcoder2-15b", "writer", 0, True),
        ("qwen3-8b", "chat", 0, False),
    ]
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i, (model_name, role, tools, cot) in enumerate(workflow):
        names = sorted(profiles)
        obs = StageObservation(
            app=7, role=i, position=i / 3, invocation_idx=i,
            tools_available=tools, cot=cot, prompt_len=64,
            model_id=names.index(model_name),
            text="detailed travel booking plan please " * 8)
        plan = ctl.plan(stage_id=i, job_id=0, obs=obs, interactive=True,
                        nodes=[n.signal() for n in nodes],
                        t_act_of=lambda sig, m: nodes[sig.node_id]
                        .residency.activation_latency(m),
                        c_deg_of=lambda sig, rq: 0.0)
        node = nodes[plan.node_id if plan.node_id is not None else 0]
        print(f"[serve] stage {i} ({role}/{model_name}): "
              f"L_hat={plan.l_hat:.0f} p_tool={plan.p_tool:.2f} "
              f"R_need={plan.r_need/1e3:.1f}KB -> node {node.node_id} "
              f"(score={plan.score:.3f})")
        node.submit(model_name, Request(
            req_id=i, tokens=list(rng.integers(0, 256, 12)), max_new=8,
            pred_len=plan.l_hat))
        out = []
        while not out:
            res = node.step()
            out = res.get(model_name, [])
        actual = len(out[0].out)
        ctl.observe_completion(obs, plan, actual_len=actual,
                               actual_kv=plan.r_kv_hat * 0.9,
                               job_remaining_after_s=1.0 * (3 - i))
        print(f"         generated {actual} tokens: {out[0].out}")
    print(f"[serve] workflow complete in {time.time()-t0:.1f}s wall; "
          f"rho={ctl.rho.rho:.3f}")
    for n in nodes:
        warm = list(n.signal().warm_models)
        print(f"[serve] node {n.node_id}: warm={warm} "
              f"headroom={n.acc.headroom/1e6:.0f}MB")


if __name__ == "__main__":
    main()
