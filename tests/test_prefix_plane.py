"""Cross-stage prefix-cache PLANE: team-trace generation invariants, block
token materialization, prefix-affinity routing, tail-percentile telemetry,
live gateway reuse end-to-end, and zero-extra-IPC digest transport."""
import dataclasses
import types

import numpy as np
import pytest

from _stubs import StubPred
from repro.core.sched.fitness import (FitnessRouter, FitnessWeights,
                                      NodeSignal, StageRequest)
from repro.data.tracegen import generate_team_trace, generate_trace
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.telemetry import Telemetry

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])
ZOO_NAMES = ("qwen3-8b",)     # single-model zoo: every model_id maps to it


@pytest.fixture(scope="module")
def zoo_host():
    return build_zoo(ZOO_NAMES, seed=1)


def _fleet(zoo_host, prefix_cache, n_nodes=2):
    zoo, host = zoo_host
    nodes = tuple(NodeSpec(i % 2, max_slots=2, s_max=192,
                           prefix_cache=prefix_cache)
                  for i in range(n_nodes))
    return build_fleet(ClusterSpec(nodes=nodes, rtt_s=RTT,
                                   model_names=ZOO_NAMES),
                       zoo=zoo, host=host)


# --------------------------------------------------------------- tracegen
def test_team_trace_deterministic_and_dag_valid():
    a = generate_team_trace(12, seed=5)
    b = generate_team_trace(12, seed=5)
    assert [dataclasses.asdict(j) for j in a] \
        == [dataclasses.asdict(j) for j in b]
    assert generate_team_trace(12, seed=6) != a      # seed actually matters
    for job in a:
        sids = [s.stage_id for s in job.stages]
        for s in job.stages:
            for d in s.deps:
                assert d in sids and d < s.stage_id   # deps precede, in-job
            assert s.prompt_blocks, "team stages must carry prompt blocks"


def test_team_trace_child_blocks_extend_parent():
    """Every dependent stage's block sequence starts with its first
    parent's full sequence — the structural invariant prefix reuse needs —
    and same-team jobs share the leading system block."""
    jobs = generate_team_trace(9, seed=2, n_teams=3)
    for job in jobs:
        by_id = {s.stage_id: s for s in job.stages}
        for s in job.stages:
            if s.deps:
                parent = by_id[s.deps[0]]
                n = len(parent.prompt_blocks)
                assert s.prompt_blocks[:n] == parent.prompt_blocks
                assert len(s.prompt_blocks) == n + 3   # reply + role + turn
            else:
                assert s.prompt_blocks[0][0] == f"team{job.job_id % 3}:sys"
        assert all(s.obs.prompt_len
                   == 32 * sum(n for _, n in s.prompt_blocks)
                   for s in job.stages)


def test_classic_trace_untouched_by_block_field():
    """generate_trace output is byte-identical across calls and carries no
    blocks; jobs_from_trace on it never consults the block helper (legacy
    token streams stay on the shared rng)."""
    t1, t2 = generate_trace(6, seed=3), generate_trace(6, seed=3)
    assert [dataclasses.asdict(j) for j in t1] \
        == [dataclasses.asdict(j) for j in t2]
    assert all(s.prompt_blocks is None for j in t1 for s in j.stages)
    l1 = jobs_from_trace(t1, seed=9)
    l2 = jobs_from_trace(t2, seed=9)
    assert [s.tokens for j in l1 for s in j.stages] \
        == [s.tokens for j in l2 for s in j.stages]


def test_block_tokens_shared_across_stages():
    """Stages sharing leading blocks materialize to identical leading
    tokens — across stages of one job AND across jobs of one team."""
    jobs = generate_team_trace(8, seed=1, n_teams=2, sys_tokens=32)
    live = jobs_from_trace(jobs, gen_cap=4)
    toks = {s.stage_id: s.tokens for j in live for s in j.stages}
    blocks = {s.stage_id: s.prompt_blocks for j in jobs for s in j.stages}
    for j in jobs:
        for s in j.stages:
            assert len(toks[s.stage_id]) \
                == sum(n for _, n in s.prompt_blocks)
            if s.deps:
                p = s.deps[0]
                assert toks[s.stage_id][:len(toks[p])] == toks[p]
    # cross-job: same team => same 32 leading (system-block) tokens
    roots = [s for j in jobs for s in j.stages if not s.deps]
    by_team = {}
    for s in roots:
        by_team.setdefault(blocks[s.stage_id][0][0], []).append(
            toks[s.stage_id][:32])
    for variants in by_team.values():
        assert all(v == variants[0] for v in variants)
    assert len(by_team) == 2 and \
        by_team["team0:sys"][0] != by_team["team1:sys"][0]


# ------------------------------------------------------- prefix affinity
def test_fitness_prefix_affinity_chain_walk():
    r = FitnessRouter(RTT, weights=FitnessWeights(w_prefix=1.0))
    sig = NodeSignal(node_id=0, cluster_id=0, headroom=1e9,
                     queue_delay_s=0.0, warm_models={},
                     prefix_digests=("a", "b", "z"))
    req = StageRequest(stage_id=0, model="m", r_need=1.0, interactive=True,
                       src_cluster=0, t_exec=1.0,
                       prefix_digests=("a", "b", "c", "d"))
    assert r.prefix_affinity(sig, req) == pytest.approx(0.5)  # stops at c
    req_none = dataclasses.replace(req, prefix_digests=())
    assert r.prefix_affinity(sig, req_none) == 0.0
    r0 = FitnessRouter(RTT)                                   # w_prefix=0
    assert r0.prefix_affinity(sig, req) == 0.0


# ------------------------------------------------------------- telemetry
def test_telemetry_tail_percentiles():
    t = Telemetry()
    jobs = []
    finish = {}
    for i in range(100):
        ev = t.event(i, i, True)
        ev.ready_t, ev.dispatch_t = 0.0, 0.01 * i
        ev.start_t = ev.dispatch_t
        ev.finish_t = 0.01 * i + 1.0
        ev.prompt_tokens, ev.prefill_avoided = 100, 40
        jobs.append(types.SimpleNamespace(
            job_id=i, interactive=True, arrival_s=0.0, deadline_s=10.0,
            stages=[types.SimpleNamespace(stage_id=i)]))
        finish[i] = ev.finish_t
    m = t.summary("x", jobs, finish, 10.0, 2.0)
    assert m.p95_latency_s <= m.p99_latency_s <= m.p999_latency_s
    assert m.queue_delay_p95_s <= m.queue_delay_p99_s \
        <= m.queue_delay_p999_s
    assert m.stage_latency_p95_s <= m.stage_latency_p99_s \
        <= m.stage_latency_p999_s
    # stage latency is ready->finish = dispatch_wait + 1.0 here
    assert m.stage_latency_p95_s == pytest.approx(
        float(np.percentile([0.01 * i + 1.0 for i in range(100)], 95)))
    assert m.prefill_tokens_total == 100 * 100
    assert m.prefill_tokens_avoided == 100 * 40
    # empty run: every tail column is exactly 0.0 (p95 job latency keeps
    # its historical inf-on-empty convention; p99/p99.9 must never emit
    # NaN/inf into fleet-summed benchmark payloads)
    e = Telemetry().summary("x", [], {}, 10.0, 0.0)
    assert e.p95_latency_s == float("inf")
    assert e.p99_latency_s == 0.0 and e.p999_latency_s == 0.0
    assert e.stage_latency_p999_s == 0.0 and e.queue_delay_p999_s == 0.0


# ------------------------------------------------------ live gateway e2e
def test_gateway_prefix_reuse_end_to_end(zoo_host):
    """Team trace through maestro-prefix on a prefix-enabled fleet: a
    substantial fraction of prefill tokens is served from cached pages,
    the per-node index counters surface in prefix_stats, and the digests
    ride the NodeSignal snapshot."""
    fleet = _fleet(zoo_host, prefix_cache=True)
    trace = generate_team_trace(4, rate=4.0, seed=0)
    jobs = jobs_from_trace(trace, n_clusters=2, gen_cap=4)
    gw = ClusterGateway(fleet, RTT, predictor=StubPred(),
                        policy="maestro-prefix")
    m = gw.run(jobs)
    assert m.run_outcome == "completed" and m.finished_jobs == 4
    assert m.prefill_tokens_total > 0
    frac = m.prefill_tokens_avoided / m.prefill_tokens_total
    assert frac >= 0.2, f"only {frac:.0%} of prefill tokens avoided"
    assert m.prefix_stats["prefix_hits"] > 0
    assert m.prefix_stats["prefix_tokens_avoided"] \
        == m.prefill_tokens_avoided
    assert any(gw.signal(nid).prefix_digests for nid in gw.node_ids())
    # routing inputs: the gateway-side digests match the engine namespace
    some = next(s for j in jobs for s in [j.stages[0]])
    digs = gw.prefix_digests(gw.view(some))
    assert digs and all(isinstance(d, str) for d in digs)


def test_gateway_disabled_cache_reports_nothing(zoo_host):
    fleet = _fleet(zoo_host, prefix_cache=False)
    trace = generate_team_trace(2, rate=4.0, seed=0)
    jobs = jobs_from_trace(trace, n_clusters=2, gen_cap=4)
    gw = ClusterGateway(fleet, RTT, predictor=StubPred(), policy="maestro")
    m = gw.run(jobs)
    assert m.finished_jobs == 2
    assert m.prefill_tokens_avoided == 0 and m.prefix_stats == {}


# ----------------------------------------------------- zero-IPC transport
def test_ipc_calls_unchanged_by_prefix_plane():
    """Digest transport rides existing messages: enabling the prefix cache
    on a worker-process fleet adds ZERO IPC round trips on a classic
    (block-free) trace — same trace, same policy, same ipc_calls."""
    trace = generate_trace(2, seed=4)
    calls = {}
    for enabled in (False, True):
        nodes = (NodeSpec(0, max_slots=2, prefix_cache=enabled),)
        fleet = build_fleet(ClusterSpec(nodes=nodes, rtt_s=RTT,
                                        model_names=ZOO_NAMES),
                            backend="process")
        try:
            gw = ClusterGateway(
                fleet, RTT, policy="fcfs",
                cfg=GatewayConfig(node_backend="process"))
            m = gw.run(jobs_from_trace(trace, n_clusters=2, gen_cap=4))
        finally:
            from repro.serving.worker import close_fleet
            close_fleet(fleet)
        assert m.finished_jobs == 2
        calls[enabled] = m.ipc_calls
    assert calls[True] == calls[False], \
        f"prefix plane changed IPC round trips: {calls}"
