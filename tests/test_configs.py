"""Config system: registry completeness, analytic param model vs real trees,
shape-suite applicability."""
import jax
import pytest

from repro.configs import SHAPES, get_config, input_specs, list_configs
from repro.models import build_model
from repro.models.common import pad_vocab, tree_params

ALL_ARCHS = [
    "qwen3-32b", "starcoder2-15b", "qwen3-8b", "qwen1.5-110b",
    "whisper-medium", "llama-3.2-vision-11b", "mamba2-2.7b",
    "moonshot-v1-16b-a3b", "llama4-scout-17b-a16e", "jamba-v0.1-52b",
]


def test_all_archs_registered():
    assert sorted(list_configs()) == sorted(ALL_ARCHS)
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_count_matches_tree(name):
    """Analytic param_count == the real parameter tree (mod vocab padding)."""
    cfg = get_config(name)
    model = build_model(cfg)
    tree_n = tree_params(model.param_defs())
    pad = pad_vocab(cfg.vocab, 256) - cfg.vocab
    n_embed_mats = 1 if cfg.tie_embeddings else 2
    expected = cfg.param_count() + pad * cfg.d_model * n_embed_mats
    assert tree_n == expected, (name, tree_n, expected)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_headline_param_count(name):
    """Sanity: total params within expected range of the marketing size."""
    cfg = get_config(name)
    n = cfg.param_count() / 1e9
    lo, hi = {
        "qwen3-32b": (28, 36), "starcoder2-15b": (13, 18),
        "qwen3-8b": (7, 9.5), "qwen1.5-110b": (95, 120),
        "whisper-medium": (0.25, 1.2), "llama-3.2-vision-11b": (9, 13),
        "mamba2-2.7b": (2.2, 3.2), "moonshot-v1-16b-a3b": (25, 31),
        "llama4-scout-17b-a16e": (95, 112), "jamba-v0.1-52b": (45, 60),
    }[name]
    assert lo <= n <= hi, (name, n)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_moe_active_params(name):
    cfg = get_config(name)
    if cfg.moe is None:
        assert cfg.active_param_count() == cfg.param_count()
    else:
        assert cfg.active_param_count() < cfg.param_count()


def test_shape_suite_skips():
    for name in ALL_ARCHS:
        cfg = get_config(name)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in cfg.applicable_shapes()
        else:
            assert "long_500k" in cfg.skipped_shapes()
        assert "train_4k" in cfg.applicable_shapes()


def test_input_specs_no_allocation():
    for name in ALL_ARCHS:
        cfg = get_config(name)
        for shape in cfg.applicable_shapes():
            specs = input_specs(cfg, shape)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
            sh = SHAPES[shape]
            assert specs["tokens"].shape[0] == sh["global_batch"]


def test_kv_bytes_per_token():
    assert get_config("mamba2-2.7b").kv_bytes_per_token() == 0
    assert get_config("mamba2-2.7b").ssm_state_bytes() > 0
    jamba = get_config("jamba-v0.1-52b")
    # 4 attention layers of 32
    assert jamba.n_attn_layers == 4
    assert jamba.kv_bytes_per_token() == 4 * 2 * 8 * 128 * 2


def test_reduced_configs_are_small():
    for name in ALL_ARCHS:
        r = get_config(name).reduced()
        assert r.param_count() < 50e6, name
        assert r.layer_pattern_period == get_config(name).layer_pattern_period
