"""Shared test doubles for the scheduling/serving suites."""


class StubPred:
    """Duck-typed MaestroPred: fixed (or callable-per-observation) length
    predictions, no training required."""

    def __init__(self, length=12.0, p_tool=0.0):
        self.length, self.p_tool = length, p_tool

    def predict_one(self, obs):
        l = self.length(obs) if callable(self.length) else self.length
        return {"length": float(l), "p_tool": float(self.p_tool)}
