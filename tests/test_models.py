"""Per-arch smoke tests (reduced configs): forward/train step on CPU with
shape + finiteness assertions, and prefill/decode agreement with the full
forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_model
from repro.training import OptConfig, adamw_init, make_train_step

ALL_ARCHS = sorted(list_configs())


def _inputs(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    extras = {}
    if cfg.encoder is not None:
        extras["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)
    if cfg.cross_attn is not None and cfg.family == "vlm":
        extras["ctx_embeds"] = jax.random.normal(
            key, (B, cfg.cross_attn.n_ctx_tokens, cfg.d_model), cfg.dtype)
    return toks, extras


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    toks, extras = _inputs(cfg, key)
    hidden = model.backbone(params, toks, extras, remat=False)
    assert hidden.shape == (*toks.shape, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1)
    step = make_train_step(model, opt_cfg)
    opt = adamw_init(params)
    batch = {"tokens": toks, "labels": toks, **extras}
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    if cfg.moe is not None:  # drop-free capacity so paths are comparable
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 32
    toks, extras = _inputs(cfg, key, B, S)
    hidden = model.backbone(params, toks, extras, remat=False)
    full_logits = hidden @ model.unembed_weight(params)

    logits_p, cache = model.prefill(params, toks[:, :S - 1], extras)
    structs, _ = model.cache_specs(B, S)
    cache_full = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), structs)

    def copy_in(dst, src):
        for k in dst:
            if isinstance(dst[k], dict):
                copy_in(dst[k], src[k])
            elif k in ("k", "v"):
                if dst[k].shape[2] == src[k].shape[2]:
                    dst[k] = src[k]
                else:
                    dst[k] = dst[k].at[:, :, :S - 1].set(src[k])
            else:
                dst[k] = src[k]
        return dst

    cache_full = copy_in(cache_full, cache)
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits_d, new_cache = model.decode_step(params, cache_full,
                                            toks[:, S - 1:S], pos)
    a = np.asarray(full_logits[:, S - 2], np.float32)
    b = np.asarray(logits_p, np.float32)
    assert np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9) < 2e-2
    c = np.asarray(full_logits[:, S - 1], np.float32)
    d = np.asarray(logits_d, np.float32)
    assert np.max(np.abs(c - d)) / (np.max(np.abs(c)) + 1e-9) < 2e-2
    # cache pytree is donate-compatible (same structure/shapes)
    assert (jax.tree.structure(new_cache)
            == jax.tree.structure(cache_full))


def test_loss_decreases_on_tiny_task():
    """A few steps of training on a repetitive sequence reduces loss."""
    cfg = get_config("qwen3-8b").reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=1, weight_decay=0.0)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt = adamw_init(params)
    toks = jnp.tile(jnp.arange(16, dtype=jnp.int32), (4, 4))  # [4, 64]
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]


def test_microbatch_equivalence():
    """n_micro=2 gradient accumulation ~ single-batch gradients."""
    cfg = get_config("starcoder2-15b").reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, dtype_override=jnp.float32)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    opt_cfg = OptConfig(warmup_steps=1)
    opt = adamw_init(params)
    p1, _, m1 = jax.jit(make_train_step(model, opt_cfg, n_micro=1))(
        params, opt, batch)
    opt = adamw_init(params)
    p2, _, m2 = jax.jit(make_train_step(model, opt_cfg, n_micro=2))(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-4)
