"""Physical paged-KV arena: pool<->arena mirror invariants, plane sharing,
geometric growth, and paged-vs-dense decode parity on real engines."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool
from repro.models import build_model
from repro.serving.engine import Engine, Request
from repro.serving.kv_arena import NULL_ROW, KVArena

GEO = dict(n_layers=2, n_kv_heads=2, head_dim=32, dtype="float32")
ALPHA = 2 * 2 * 2 * 32 * 4          # bytes/token for GEO at f32


def _bind(arena, acc, name="m"):
    pool = VirtualKVPool(acc, page_bytes=ALPHA * arena.page_tokens,
                         page_tokens=arena.page_tokens)
    return arena.register(name, pool, s_max=256, **GEO)


def test_every_grant_has_exactly_one_row():
    acc = MemoryAccountant(m_total=4e6)
    arena = KVArena(page_tokens=16)
    b = _bind(arena, acc)
    assert b.alloc_seq(0, "m", tokens=40)        # 3 pages
    assert b.alloc_seq(1, "m", tokens=10)        # 1 page
    rows = b.seq_rows(0) + b.seq_rows(1)
    assert len(rows) == 4 and len(set(rows)) == 4
    assert NULL_ROW not in rows
    assert arena.check_mirror()
    # on-demand growth maps fresh rows for the new pages only
    assert b.ensure_tokens(0, 100)               # 3 -> 7 pages
    assert len(b.seq_rows(0)) == 7
    assert b.seq_rows(0)[:3] == rows[:3]         # existing pages keep rows
    assert arena.check_mirror()


def test_free_returns_pages_to_both_pool_and_plane():
    acc = MemoryAccountant(m_total=4e6)
    arena = KVArena(page_tokens=16)
    b = _bind(arena, acc)
    assert b.alloc_seq(0, "m", tokens=64)
    assert acc.m_kv > 0 and arena.mapped_rows() > 0
    b.free_seq(0)
    assert not b.pool.seqs and not b.row_of
    assert arena.mapped_pages() == 0 and arena.mapped_rows() == 0
    assert acc.m_kv == pytest.approx(0.0)        # unmapped -> accountant
    assert arena.check_mirror()


def test_colocated_models_share_one_plane():
    acc = MemoryAccountant(m_total=8e6)
    arena = KVArena(page_tokens=16)
    a = _bind(arena, acc, "model-a")
    b = _bind(arena, acc, "model-b")
    assert a.plane is b.plane                    # same geometry, one store
    assert a.alloc_seq(0, "model-a", tokens=40)
    assert b.alloc_seq(1, "model-b", tokens=40)
    assert not set(a.seq_rows(0)) & set(b.seq_rows(1))
    # a different geometry gets its own plane
    pool = VirtualKVPool(acc, page_bytes=1024, page_tokens=16)
    c = arena.register("model-c", pool, s_max=64, n_layers=4, n_kv_heads=1,
                       head_dim=16, dtype="float32")
    assert c.plane is not a.plane and len(arena.planes) == 2
    assert arena.check_mirror()


def test_mirror_invariant_under_random_churn():
    rng = np.random.default_rng(7)
    acc = MemoryAccountant(m_total=2e6)
    arena = KVArena(page_tokens=16, init_rows=2)  # force plane growth
    b = _bind(arena, acc)
    live = []
    sid = 0
    for _ in range(200):
        op = rng.integers(0, 3)
        if op == 0:
            if b.alloc_seq(sid, "m", tokens=int(rng.integers(1, 120))):
                live.append(sid)
            sid += 1
        elif op == 1 and live:
            b.ensure_tokens(rng.choice(live), int(rng.integers(1, 200)))
        elif op == 2 and live:
            live.remove(victim := rng.choice(live))
            b.free_seq(int(victim))
        assert arena.check_mirror()
        assert acc.check_invariant()
        assert acc.m_kv == b.pool.n_pages * b.pool.page_bytes
    for s in live:
        b.free_seq(s)
    assert arena.mapped_pages() == 0 and acc.m_kv == pytest.approx(0.0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _run(m, params, kv_backend, prompts, max_new=6, s_max=64):
    eng = Engine(m, params, MemoryAccountant(m_total=256e6), max_slots=2,
                 s_max=s_max, kv_backend=kv_backend)
    for i, p in enumerate(prompts):
        eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new))
    out = {r.req_id: r.out for r in eng.drain()}
    return eng, out


def test_paged_decode_matches_dense_token_for_token(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, int(n)) for n in (8, 5, 17, 11)]
    _, dense = _run(m, params, "dense", prompts)
    eng, paged = _run(m, params, "ref", prompts)
    assert eng.paged and eng.kv_backend == "ref"
    assert paged == dense
    assert eng.arena.check_mirror()
    assert eng.arena.mapped_pages() == 0          # drained -> all reclaimed


def test_engine_eviction_returns_pages_to_pool_and_arena(tiny):
    cfg, m, params = tiny
    eng = Engine(m, params, MemoryAccountant(m_total=256e6), max_slots=2,
                 s_max=64)
    eng.submit(Request(req_id=0, tokens=[1, 2, 3, 4], max_new=32))
    eng.step()
    assert eng.arena.mapped_pages() > 0
    req = eng.evict(0)
    assert req is not None and req.out == []
    assert eng.arena.mapped_pages() == 0 and eng.arena.mapped_rows() == 0
    assert eng.acc.m_kv == pytest.approx(0.0)
    assert eng.arena.check_mirror()


def test_hybrid_engine_pages_attn_and_keeps_ssm_state():
    cfg = get_config("jamba-v0.1-52b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    _, n_layers, _, _, _ = m.paged_kv_layout()
    assert 0 < n_layers < cfg.n_layers            # truly hybrid
    eng, out = _run(m, params, None, [[5, 6, 7], [9, 8, 7, 6]], max_new=4)
    assert eng.paged
    assert all(len(o) >= 4 for o in out.values())
    structs, _ = m.state_cache_specs(2, 64)
    assert structs                                # SSM state stayed dense
    assert all("k" not in entry for entry in structs.values())
    assert eng.arena.check_mirror()
