"""Percentile edge cases + fault/tail columns in the telemetry plane.

The fleet-summed benchmark paths serialize ``GatewayMetrics.row()`` straight
into BENCH JSON: a NaN (np.percentile of an empty array) or an IndexError
on a single-sample run would poison every downstream comparison, so the
extreme-tail columns (p99/p99.9) are pinned to 0.0 below two samples."""
import math
import types

import pytest

from repro.serving.telemetry import (NodeDeathEvent, Telemetry,
                                     tail_percentile)


def _job(i, stage_ids, interactive=True, arrival=0.0, deadline=10.0):
    return types.SimpleNamespace(
        job_id=i, interactive=interactive, arrival_s=arrival,
        deadline_s=deadline,
        stages=[types.SimpleNamespace(stage_id=s) for s in stage_ids])


def test_tail_percentile_edge_cases():
    assert tail_percentile([], 99) == 0.0
    assert tail_percentile([], 99.9) == 0.0
    assert tail_percentile([3.5], 99) == 0.0          # single sample: noise
    assert tail_percentile([1.0, 2.0], 50) == pytest.approx(1.5)
    xs = [float(i) for i in range(1000)]
    assert tail_percentile(xs, 99.9) > tail_percentile(xs, 99)
    assert not math.isnan(tail_percentile([], 99))


def test_summary_empty_run_has_no_nan():
    m = Telemetry().summary("x", [], {}, 10.0, 0.0)
    assert m.p99_latency_s == 0.0 and m.p999_latency_s == 0.0
    assert m.queue_delay_p99_s == 0.0 and m.queue_delay_p999_s == 0.0
    assert m.stage_latency_p99_s == 0.0 and m.stage_latency_p999_s == 0.0
    assert m.recovery_time_s == 0.0
    assert m.stages_by_model == {} and m.tokens_by_model == {}
    # nothing in the whole row is NaN (json.dumps would emit invalid JSON)
    for k, v in m.row().items():
        if isinstance(v, float):
            assert not math.isnan(v), k


def test_summary_single_sample_run():
    t = Telemetry()
    ev = t.event(0, 0, True)
    ev.ready_t, ev.dispatch_t, ev.start_t, ev.finish_t = 0.0, 0.1, 0.1, 1.0
    m = t.summary("x", [_job(0, [0])], {0: 1.0}, 10.0, 1.0)
    # p95 keeps the observation; the extreme tails refuse to extrapolate
    assert m.p95_latency_s == pytest.approx(1.0)
    assert m.p99_latency_s == 0.0 and m.p999_latency_s == 0.0
    assert m.queue_delay_p999_s == 0.0 and m.stage_latency_p999_s == 0.0


def test_summary_fleet_tails_monotone():
    t = Telemetry()
    jobs, finish = [], {}
    for i in range(200):
        ev = t.event(i, i, True)
        ev.ready_t, ev.dispatch_t = 0.0, 0.002 * i
        ev.start_t, ev.finish_t = 0.002 * i, 0.002 * i + 1.0
        jobs.append(_job(i, [i]))
        finish[i] = ev.finish_t
    m = t.summary("x", jobs, finish, 10.0, 2.0)
    assert m.p95_latency_s <= m.p99_latency_s <= m.p999_latency_s
    assert m.queue_delay_p95_s <= m.queue_delay_p99_s \
        <= m.queue_delay_p999_s
    assert m.stage_latency_p95_s <= m.stage_latency_p99_s \
        <= m.stage_latency_p999_s


def test_recovery_time_from_death_events():
    t = Telemetry()
    for sid, fin in ((0, 4.0), (1, 6.5), (2, 2.0)):
        ev = t.event(sid, sid, False)
        ev.ready_t, ev.finish_t = 0.0, fin
        ev.model = "qwen3-8b" if sid < 2 else "whisper-medium"
        ev.out_len = 10 * (sid + 1)
    # death at t=3 evacuated stages 0 and 1; the last one landed at 6.5
    t.node_death(NodeDeathEvent(node_id=0, t=3.0, cause="test",
                                requeued_stages=(0, 1)))
    jobs = [_job(i, [i], interactive=False, deadline=100.0)
            for i in range(3)]
    m = t.summary("x", jobs, {0: 4.0, 1: 6.5, 2: 2.0}, 10.0, 7.0)
    assert m.recovery_time_s == pytest.approx(3.5)
    assert m.stages_by_model == {"qwen3-8b": 2, "whisper-medium": 1}
    assert m.tokens_by_model == {"qwen3-8b": 30, "whisper-medium": 30}
    # a death whose evacuated stages never finished contributes nothing
    t2 = Telemetry()
    t2.node_death(NodeDeathEvent(node_id=1, t=1.0, cause="test",
                                 requeued_stages=(7,)))
    assert t2.summary("x", [], {}, 10.0, 2.0).recovery_time_s == 0.0
