"""Property-based tests (hypothesis) pulled out of the per-subsystem suites
so the tier-1 suite still collects on a bare environment: this module is
skipped wholesale when hypothesis is unavailable (``pip install -e .[test]``
brings it in), while the deterministic tests in test_predictor / test_runtime
/ test_sched always run."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st                       # noqa: E402
from hypothesis import given, settings                   # noqa: E402

from repro.core.predictor import IsotonicCalibrator      # noqa: E402
from repro.core.predictor.cost_model import (            # noqa: E402
    HardwareSpec, synthetic_profile)
from repro.core.runtime.accounting import (              # noqa: E402
    AdmissionError, MemoryAccountant)
from repro.core.runtime.coordination import (            # noqa: E402
    Action, EngineInfo, EngineState, plan_degradation)
from repro.core.runtime.kv_pool import VirtualKVPool     # noqa: E402
from repro.core.runtime.residency import (               # noqa: E402
    HierarchicalResidency, ModelState)
from repro.core.sched.fitness import RobustNormalizer    # noqa: E402
from repro.data.tracegen import (                        # noqa: E402
    DiurnalArrivals, MarkovModulatedArrivals, PoissonArrivals)

PROFILES = {f"m{i}": synthetic_profile(f"m{i}", params_b=0.5 + i)
            for i in range(6)}


# ------------------------------------------------------------- predictor
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.integers(0, 1)),
                min_size=5, max_size=200))
def test_isotonic_monotone_property(pairs):
    scores = np.array([p[0] for p in pairs])
    labels = np.array([float(p[1]) for p in pairs])
    iso = IsotonicCalibrator().fit(scores, labels)
    # transform is monotone non-decreasing on any query grid
    grid = np.linspace(0, 1, 64)
    out = iso.transform(grid)
    assert np.all(np.diff(out) >= -1e-9)
    assert np.all((out >= 0) & (out <= 1))


# --------------------------------------------------------------- runtime
@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
def test_residency_capacity_invariants(requests):
    res = HierarchicalResidency(PROFILES, c_gpu=12e9, c_cpu=20e9, c_disk=60e9)
    for r in requests:
        ok, t_act = res.ensure_gpu(f"m{r}")
        assert ok and t_act >= 0.0
        # tier capacity invariants after every operation
        assert res.used("gpu") <= res.cap["gpu"]
        assert res.used("cpu") <= res.cap["cpu"]
        assert res.used("disk") <= res.cap["disk"]
        # requested model is RUNNING and tracked on GPU
        assert res.state[f"m{r}"] is ModelState.RUNNING
        assert f"m{r}" in res.lru["gpu"]
        # LRU sets and states agree
        for m, s in res.state.items():
            if s is ModelState.RUNNING:
                assert m in res.lru["gpu"]
            if s is ModelState.DISK:
                assert m in res.lru["disk"]


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["admit", "release"]),
                          st.floats(1e6, 5e8)), min_size=1, max_size=50))
def test_accounting_invariant(ops):
    acc = MemoryAccountant(m_total=2e9, m_other=1e8)
    acc.register_context("m", 2e8)
    admitted = []
    for kind, amt in ops:
        if kind == "admit":
            if acc.can_admit(amt):
                acc.admit_kv(amt)
                admitted.append(amt)
            else:
                with pytest.raises(AdmissionError):
                    acc.admit_kv(amt)
        elif admitted:
            acc.release_kv(admitted.pop())
        assert acc.check_invariant()
        assert acc.headroom >= -1e-6


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 400), st.integers(0, 3)),
                min_size=1, max_size=30))
def test_kv_pool_consistency(seq_specs):
    acc = MemoryAccountant(m_total=1e9)
    pool = VirtualKVPool(acc, page_bytes=1 << 20, page_tokens=16)
    pool.set_virtual_budget("m", 3e9)   # overcommitted vs physical
    live = {}
    for i, (tokens, action) in enumerate(seq_specs):
        if action == 0 or not live:
            if pool.alloc_seq(i, "m", tokens):
                live[i] = tokens
        elif action == 1:
            sid = next(iter(live))
            if pool.extend_seq(sid, tokens):
                live[sid] += tokens
        else:
            sid = next(iter(live))
            pool.free_seq(sid)
            del live[sid]
        # invariants
        assert acc.check_invariant()
        assert pool.physical_used() <= acc.m_kv + 1e-6
        assert 0.0 <= pool.fragmentation() <= 1.0
        # no page is double-owned
        owned = [p for s in pool.seqs.values() for p in s.pages]
        assert len(owned) == len(set(owned))
        assert not (set(owned) & set(pool.free_pages))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(
    st.sampled_from(list(EngineState)),
    st.floats(1e8, 8e9),   # weights
    st.floats(1e7, 5e8),   # ctx
    st.floats(0, 8e9)),    # kv
    min_size=1, max_size=8),
    st.floats(1e8, 2e10))
def test_degradation_plan_properties(engines_raw, required):
    engines = [EngineInfo(f"e{i}", s, w, c, kv, int(kv / 1e5) + 1)
               for i, (s, w, c, kv) in enumerate(engines_raw)]
    plan = plan_degradation(required, engines, HardwareSpec())
    if plan is not None:
        assert plan.freed >= required
        assert plan.c_deg >= 0
        # interrupts flag consistent with actions taken
        has_int = any(a in (Action.SWAP_KV, Action.ABORT)
                      for _, a in plan.steps)
        assert plan.interrupts_active == has_int
        # ordering: non-decreasing disruption priority
        prio = {EngineState.IDLE: 0, EngineState.SLEEPING: 1,
                EngineState.PENDING_SLEEP: 2, EngineState.ACTIVE: 3}
        ps = [prio[e.state] for e, _ in plan.steps]
        assert ps == sorted(ps)
    else:
        # None exactly when the greedy pass cannot free enough
        from repro.core.runtime.coordination import _best_action
        freeable = sum(_best_action(e)[1] for e in engines)
        assert freeable < required


# ------------------------------------------------------------- scheduler
@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=200),
       st.floats(-1e7, 1e7))
def test_robust_normalizer_bounds(history, query):
    n = RobustNormalizer()
    for v in history:
        n.observe("m", v)
    out = n.norm("m", query)
    assert 0.0 <= out <= 1.0


# ------------------------------------------------------------- tracegen
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 50.0),
       st.integers(1, 300))
def test_poisson_interarrivals_nonnegative(seed, rate, n):
    ts = PoissonArrivals(rate=rate).sample(np.random.default_rng(seed), n)
    assert ts.shape == (n,)
    assert ts[0] > 0 and np.all(np.diff(ts) >= 0)
    assert np.all(np.isfinite(ts))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.5, 20.0))
def test_poisson_mean_rate_converges(seed, rate):
    n = 4000
    ts = PoissonArrivals(rate=rate).sample(np.random.default_rng(seed), n)
    # empirical rate over a 4000-sample window is within 15% of nominal
    assert abs(n / ts[-1] - rate) < 0.15 * rate


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.floats(0.2, 2.0), st.floats(2.5, 20.0), st.floats(10.0, 300.0))
def test_diurnal_arrivals_properties(seed, base, peak, period):
    d = DiurnalArrivals(base_rate=base, peak_rate=peak, period_s=period)
    ts = d.sample(np.random.default_rng(seed), 200)
    assert ts[0] > 0 and np.all(np.diff(ts) >= 0)
    # the instantaneous rate profile stays inside [base, peak] everywhere
    grid = np.linspace(0.0, 3.0 * period, 512)
    rates = np.array([d.rate_at(t) for t in grid])
    assert np.all(rates >= base - 1e-9) and np.all(rates <= peak + 1e-9)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_mmpp_burst_phase_occupancy(seed):
    mm = MarkovModulatedArrivals(rates=(0.5, 12.0), dwell_s=(30.0, 8.0))
    times, phases = mm.sample_with_phases(
        np.random.default_rng(seed), 3000)
    assert times[0] > 0 and np.all(np.diff(times) >= 0)
    assert set(np.unique(phases)) == {0, 1}
    # expected share of arrivals per phase is (rate_k * dwell_k) / sum;
    # with 3000 arrivals the observed share lands within a generous band
    w = np.array(mm.rates) * np.array(mm.dwell_s)
    expect = w / w.sum()
    share1 = float(np.mean(phases == 1))
    assert 0.0 < share1 < 1.0
    assert abs(share1 - expect[1]) < 0.25
