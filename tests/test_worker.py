"""Multi-process node backend: worker protocol + inproc/process parity.

The acceptance bar for the process backend is exact reproducibility: under
the gateway's deterministic virtual clock, a fleet of worker processes must
produce the SAME completion sets and the SAME metrics as the cooperative
in-process fleet — concurrency changes wall-clock, never the outcome."""
import multiprocessing as mp

import numpy as np
import pytest

from _stubs import StubPred
from repro.data.tracegen import generate_trace
from repro.serving.cluster import (ClusterSpec, LiveJob, LiveStage, NodeSpec,
                                   build_fleet, jobs_from_trace)
from repro.serving.engine import PromptTooLongError, Request
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import (NodeHandle, WorkerSpec, close_fleet,
                                  spawn_fleet)

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])
ZOO_NAMES = ("qwen3-8b",)

# GatewayMetrics fields that legitimately differ between backends: the
# backend tag itself, the wall-clock/IPC accounting of the workers, the
# socket transport's byte counters (zero on pipe backends), and the
# engine-measured wall TTFT percentiles (real elapsed time, not virtual)
BACKEND_ONLY = {"node_backend", "ipc_calls", "ipc_wall_s",
                "worker_step_wall_s", "worker_stats",
                "rpc_bytes_sent", "rpc_bytes_recv",
                "ttft_p50_s", "ttft_p95_s"}


def _run(backend, make_jobs, specs, policy="fcfs", predictor=None):
    spec = ClusterSpec(nodes=tuple(specs), rtt_s=RTT, model_names=ZOO_NAMES)
    fleet = build_fleet(spec, backend=backend)
    try:
        gw = ClusterGateway(fleet, RTT, predictor=predictor, policy=policy,
                            cfg=GatewayConfig(node_backend=backend))
        m = gw.run(make_jobs())
        events = {sid: (e.node_id, e.out_len, e.finish_t, e.dispatch_t,
                        e.preemptions, e.queue_delay_s)
                  for sid, e in gw.telemetry.events.items()}
    finally:
        close_fleet(fleet)       # covers gateway-constructor failures too
    return m, events


def _assert_parity(m_in, ev_in, m_proc, ev_proc):
    assert set(ev_in) == set(ev_proc)              # same completion set
    assert ev_in == ev_proc                        # same nodes/times/outputs
    row_in, row_proc = m_in.row(), m_proc.row()
    for k in row_in:
        if k not in BACKEND_ONLY:
            assert row_in[k] == row_proc[k], (k, row_in[k], row_proc[k])


def test_trace_workload_parity():
    """Generated multi-agent trace over two clusters: identical completion
    sets and bit-identical metrics on inproc vs worker-process fleets, and
    the workers really did the serving (per-node IPC counters > 0)."""
    specs = [NodeSpec(0, max_slots=2), NodeSpec(1, max_slots=2)]

    def jobs():
        return jobs_from_trace(generate_trace(3, rate=2.0, seed=5),
                               n_clusters=2, prompt_cap=8, gen_cap=8, seed=2)

    m_in, ev_in = _run("inproc", jobs, specs)
    m_proc, ev_proc = _run("process", jobs, specs)
    assert m_in.finished_jobs == 3 and m_in.node_backend == "inproc"
    assert m_proc.node_backend == "process"
    _assert_parity(m_in, ev_in, m_proc, ev_proc)
    assert m_proc.ipc_calls > 0 and m_proc.ipc_wall_s > 0
    assert set(m_proc.worker_stats) == {0, 1}
    for stats in m_proc.worker_stats.values():     # every node saw traffic
        assert stats["ipc_calls"] > 0
        assert stats["worker_step_wall_s"] > 0
    assert m_in.ipc_calls == 0 and not m_in.worker_stats


def test_preemption_parity():
    """Boundary preemption (the path that reads decode progress, which lives
    in the child on the process backend) makes identical decisions."""
    specs = [NodeSpec(0, max_slots=1)]

    def jobs():
        def _obs():
            from repro.core.predictor.features import StageObservation
            return StageObservation(app=0, role=0, position=0.0,
                                    invocation_idx=0, tools_available=0,
                                    cot=False, prompt_len=32, model_id=0,
                                    text="stage", src_cluster=0)
        batch = LiveJob(0, "b", False, 0.0, [
            LiveStage(stage_id=0, job_id=0, deps=[], obs=_obs(),
                      interactive=False, tokens=[1, 2, 3, 4], max_new=40)])
        inter = LiveJob(1, "i", True, 0.3, [
            LiveStage(stage_id=1, job_id=1, deps=[], obs=_obs(),
                      interactive=True, tokens=[5, 6, 7, 8], max_new=5)])
        return [batch, inter]

    m_in, ev_in = _run("inproc", jobs, specs, policy="maestro",
                       predictor=StubPred())
    m_proc, ev_proc = _run("process", jobs, specs, policy="maestro",
                           predictor=StubPred())
    assert m_in.preemptions >= 1                   # the path was exercised
    _assert_parity(m_in, ev_in, m_proc, ev_proc)


def test_worker_handle_protocol():
    """Direct protocol exercise on one spawned worker: signal snapshots,
    admission estimates, typed error propagation, kv stats, idempotent
    shutdown."""
    h = NodeHandle(WorkerSpec(node_id=7, cluster_id=1,
                              model_names=ZOO_NAMES, max_slots=2, s_max=32))
    try:
        h.wait_ready()
        assert set(h.profiles) == set(ZOO_NAMES)
        sig = h.signal()
        assert sig.node_id == 7 and sig.cluster_id == 1
        assert sig.headroom > 0
        assert h.can_admit(1024.0, ZOO_NAMES[0])
        assert h.t_act(ZOO_NAMES[0]) > 0           # cold model
        assert h.degradation_cost(0.0) == 0.0
        with pytest.raises(PromptTooLongError):    # typed, not RuntimeError
            h.submit(ZOO_NAMES[0], Request(req_id=1,
                                           tokens=list(range(40)),
                                           max_new=4))
        h.submit(ZOO_NAMES[0], Request(req_id=2, tokens=[1, 2, 3],
                                       max_new=3))
        out = {}
        for _ in range(20):
            for model, reqs in h.step().items():
                for r in reqs:
                    out[r.req_id] = r
            if out:
                break
        assert out[2].out and len(out[2].out) == 3
        stats = h.kv_stats()
        assert stats["n_engines"] == 1
        assert stats["arena_peak_pages"] > 0
        assert h.worker_stats()["ipc_calls"] == h.ipc_calls > 0
    finally:
        h.close()
        h.close()                                  # second close is a no-op
    assert not h.proc.is_alive()


def test_partial_spawn_failure_leaks_no_workers():
    """If one node of a fleet fails its boot handshake, spawn_fleet tears
    down every already-started worker before raising — a failed spawn
    leaves no orphan processes behind (regression: the old loop started
    workers one by one and abandoned the live ones on the first failure)."""
    before = {p.pid for p in mp.active_children()}
    specs = [WorkerSpec(node_id=0, cluster_id=0, model_names=ZOO_NAMES),
             WorkerSpec(node_id=1, cluster_id=0,
                        model_names=("no-such-model",))]
    with pytest.raises(RuntimeError, match="failed to boot"):
        spawn_fleet(specs)
    leaked = [p for p in mp.active_children()
              if p.pid not in before and p.is_alive()]
    assert not leaked, f"spawn failure leaked workers: {leaked}"


def test_close_fleet_safe_on_half_constructed_handles():
    """close_fleet / handle.close must be callable on handles whose
    constructor never completed (no process, no pipe) and must be
    idempotent — this is the teardown path of a failed spawn."""
    h = NodeHandle.__new__(NodeHandle)
    h._init_state(WorkerSpec(node_id=3, cluster_id=0,
                             model_names=ZOO_NAMES))
    close_fleet([h, object()])     # non-handle members are skipped
    close_fleet([h])               # second close is a no-op


def test_process_backend_requires_worker_fleet(zoo_host=None):
    """Config/fleet mismatch is a construction-time error, not a hang."""
    fleet = build_fleet(ClusterSpec(nodes=(NodeSpec(0),), rtt_s=RTT,
                                    model_names=ZOO_NAMES))
    with pytest.raises(ValueError, match="process"):
        ClusterGateway(fleet, RTT, policy="fcfs",
                       cfg=GatewayConfig(node_backend="process"))
    with pytest.raises(ValueError, match="node_backend"):
        ClusterGateway(fleet, RTT, policy="fcfs",
                       cfg=GatewayConfig(node_backend="threads"))
    with pytest.raises(ValueError, match="backend"):
        build_fleet(ClusterSpec(nodes=(NodeSpec(0),), rtt_s=RTT,
                                model_names=ZOO_NAMES), backend="threads")
