"""Membership plane: FleetRegistry state machine (pure, explicit-``now``
unit tests), straggler demotion, and mid-run elastic register/retire
through the gateway."""
import numpy as np
import pytest

from repro.data.tracegen import generate_trace
from repro.distributed.fault import StragglerDetector
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway
from repro.serving.node_runtime import NodeRuntime
from repro.serving.registry import (DEAD, HEALTHY, RETIRED, SUSPECT,
                                    FleetRegistry, HeartbeatConfig)

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])


def test_heartbeat_config_validation():
    HeartbeatConfig(0.1, 0.4, 1.0)                     # valid
    with pytest.raises(ValueError):
        HeartbeatConfig(interval_s=0.0)                # no zero cadence
    with pytest.raises(ValueError):
        HeartbeatConfig(interval_s=2.0, suspect_after_s=1.0)
    with pytest.raises(ValueError):
        HeartbeatConfig(suspect_after_s=9.0, dead_after_s=5.0)


def test_liveness_state_machine():
    reg = FleetRegistry(HeartbeatConfig(0.1, 0.4, 1.0))
    reg.register(0, 0.0)
    reg.register(1, 0.0)

    assert reg.update(0.2) == []                       # everyone fresh
    assert reg.states() == {0: HEALTHY, 1: HEALTHY}

    reg.beat(1, 0.45)
    assert reg.update(0.5) == []                       # aging demotes, not kills
    assert reg.state(0) == SUSPECT
    assert "heartbeat age" in reg.members[0].suspect_cause
    assert reg.state(1) == HEALTHY
    assert reg.suspects() == [0]

    reg.beat(0, 0.6)                                   # fresh beat recovers
    assert reg.update(0.7) == []
    assert reg.states() == {0: HEALTHY, 1: HEALTHY}

    reg.beat(1, 1.9)
    assert reg.update(2.0) == [0]                      # silent past dead_after_s
    assert reg.state(0) == DEAD
    assert "timeout" in reg.members[0].death_cause
    assert reg.deaths == [0]
    assert reg.live() == [1]

    beats = reg.members[0].beats                       # dead members stay dead
    reg.beat(0, 2.1)
    reg.mark_dead(0, 2.2)
    assert reg.members[0].beats == beats and reg.deaths == [0]

    reg.register(0, 3.0)                               # replacement, same id
    reg.beat(1, 3.0)
    assert reg.state(0) == HEALTHY and reg.live() == [0, 1]
    assert reg.update(3.1) == []


def test_retire_and_transport_death():
    reg = FleetRegistry(HeartbeatConfig(0.1, 0.4, 1.0))
    for nid in (0, 1):
        reg.register(nid, 0.0)
    reg.retire(1, 0.5)
    assert reg.state(1) == RETIRED and reg.live() == [0]
    assert reg.update(5.0) == [0]                      # retired is not dead
    assert reg.deaths == [0]
    reg.retire(0, 6.0)                                 # retiring dead: no-op
    assert reg.state(0) == DEAD

    reg2 = FleetRegistry()
    reg2.register(3, 0.0)
    reg2.mark_dead(3, 0.1, cause="transport EOF")      # WorkerDied path
    assert reg2.members[3].death_cause == "transport EOF"
    assert reg2.deaths == [3]


def test_straggler_demotion_and_forget():
    det = StragglerDetector(z_thresh=1.5, min_obs=4)
    reg = FleetRegistry(HeartbeatConfig(0.1, 0.4, 1.0), detector=det)
    for nid in range(4):
        reg.register(nid, 0.0)
    for _ in range(8):                                 # node 3 is 100x slower
        for nid in range(3):
            reg.observe_step(nid, 0.01)
        reg.observe_step(3, 1.0)
    for nid in range(4):
        reg.beat(nid, 0.05)                            # heartbeats all current
    assert reg.update(0.1) == []
    assert reg.state(3) == SUSPECT                     # slow, not silent
    assert reg.members[3].suspect_cause == "straggler"
    assert reg.states() == {0: HEALTHY, 1: HEALTHY, 2: HEALTHY, 3: SUSPECT}
    assert reg.stragglers() == [3]

    reg.mark_dead(3, 0.2)                              # death forgets history
    assert 3 not in det.mean
    assert reg.stragglers() == []                      # only live members count

    reg.observe_step(9, 0.0)                           # non-positive: ignored
    assert 9 not in det.mean


def test_elastic_membership_mid_run():
    """Gateway-level elasticity under the virtual clock: a node registered
    mid-run takes real work; a retired node's in-flight stages re-enter the
    queue and finish elsewhere; the run completes."""
    spec = ClusterSpec(nodes=(NodeSpec(0), NodeSpec(1)),
                       model_names=("qwen3-8b",))
    jobs = jobs_from_trace(generate_trace(n_jobs=8, seed=9, rate=4.0),
                           n_clusters=2, gen_cap=8)
    fleet = build_fleet(spec, backend="inproc")
    gw = ClusterGateway(fleet, RTT, policy="fcfs")
    gw.submit_jobs(jobs)
    gw.clock.set_deadline(gw._auto_deadline_s(jobs))
    zoo, host = build_zoo(("qwen3-8b",), seed=1)
    added = retired = False
    requeued = []
    while gw._unfinished() and not gw.clock.expired():
        gw.step()
        if not added and len(gw.done) >= 4:
            gw.register_node(NodeRuntime(2, 1, zoo, host))
            with pytest.raises(ValueError, match="already"):
                gw.register_node(NodeRuntime(2, 1, zoo, host))
            added = True
        if added and not retired and len(gw.done) >= 8:
            requeued = gw.retire_node(0)
            retired = True
    m = gw.metrics()
    assert added and retired
    assert m.finished_jobs == len(jobs)
    assert m.liveness == {0: "retired", 1: "healthy", 2: "healthy"}
    landed = {e.node_id for e in gw.telemetry.events.values()
              if e.finish_t > 0}
    assert 2 in landed                       # the late joiner served stages
    for sid in requeued:                     # retired node's work finished
        assert gw.telemetry.events[sid].finish_t > 0
    with pytest.raises(KeyError):
        gw.retire_node(0)                    # already gone
    with pytest.raises(ValueError, match="last"):
        for nid in list(gw.fleet):
            gw.retire_node(nid)              # cannot drain the whole fleet
    assert len(gw.fleet) == 1
