"""Prefix-cache plane: digest chaining, refcounted row sharing + COW,
engine cache-hit parity (token-for-token vs cache off), and full headroom
recovery on eviction/sleep."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool
from repro.models import build_model
from repro.serving.engine import Engine, Request
from repro.serving.kv_arena import KVArena
from repro.serving.prefix_cache import (PrefixCacheConfig, page_digests,
                                        root_key)

GEO = dict(n_layers=2, n_kv_heads=2, head_dim=32, dtype="float32")
ALPHA = 2 * 2 * 2 * 32 * 4


def _bind(arena, acc, name="m"):
    pool = VirtualKVPool(acc, page_bytes=ALPHA * arena.page_tokens,
                         page_tokens=arena.page_tokens)
    return arena.register(name, pool, s_max=256, **GEO)


# --------------------------------------------------------------- digests
def test_page_digests_chained_and_namespaced():
    toks = list(range(40))
    d = page_digests(toks, 16, "model-a")
    assert len(d) == 2                      # only full pages
    assert d == page_digests(toks, 16, "model-a")          # deterministic
    assert d != page_digests(toks, 16, "model-b")          # keyed by model
    # chaining: perturbing page 0 changes every later digest too
    toks2 = [99] + toks[1:]
    d2 = page_digests(toks2, 16, "model-a")
    assert d2[0] != d[0] and d2[1] != d[1]
    # shared first page, divergent second
    toks3 = toks[:16] + [7] * 24
    d3 = page_digests(toks3, 16, "model-a")
    assert d3[0] == d[0] and d3[1] != d[1]


# ----------------------------------------------------- arena-level sharing
def test_alias_refcounts_cow_and_flush():
    acc = MemoryAccountant(m_total=4e6)
    arena = KVArena(page_tokens=16)
    b = _bind(arena, acc)
    idx = arena.enable_prefix_cache(acc, PrefixCacheConfig(max_pages=8))
    assert b.alloc_seq(0, "m", tokens=40)                  # 3 pages
    rows = b.seq_rows(0)
    toks = list(range(48))
    digs = page_digests(toks, 16, "m")
    parent = root_key("m")
    for i, d in enumerate(digs[:2]):
        assert idx.insert("m", d, parent, b.plane, rows[i],
                          toks[16 * i:16 * (i + 1)], 16 * (i + 1))
        parent = d
    assert arena.check_mirror()
    assert b.plane.refs[rows[0]] == 2                      # mapping + pin
    # pinned prefixes survive the sequence's release
    b.free_seq(0)
    assert arena.mapped_pages() == 0
    assert b.plane.refs[rows[0]] == 1 and b.plane.refs[rows[1]] == 1
    assert arena.check_mirror()
    assert acc.m_kv == pytest.approx(0.0)
    assert idx.pinned_bytes() == 2 * b.plane.spec.row_bytes
    # a new sequence aliases the cached rows instead of allocating
    assert b.alloc_seq(1, "m", tokens=40, alias_rows=rows[:2])
    assert b.seq_rows(1)[:2] == rows[:2]
    assert b.plane.refs[rows[0]] == 2
    assert arena.pages_aliased == 2
    assert arena.check_mirror()
    # COW privatises a shared page; the original row keeps its pin
    assert b.make_private(1, 0)
    assert b.seq_rows(1)[0] != rows[0]
    assert b.plane.refs[rows[0]] == 1
    assert arena.cow_copies == 1
    assert not b.make_private(1, 0)                        # already private
    assert arena.check_mirror()
    b.free_seq(1)
    # flush releases every pin and the accountant context
    idx.flush()
    assert not idx.entries and idx.pinned_bytes() == 0
    assert arena.mapped_rows() == 0
    assert all(not p.refs for p in arena.planes.values())
    assert arena.check_mirror()
    assert acc.check_invariant()
    assert "prefix-cache" not in acc.ctx


def test_index_eviction_under_cap():
    acc = MemoryAccountant(m_total=4e6)
    arena = KVArena(page_tokens=16)
    b = _bind(arena, acc)
    idx = arena.enable_prefix_cache(acc, PrefixCacheConfig(max_pages=2))
    assert b.alloc_seq(0, "m", tokens=80)                  # 5 pages
    rows = b.seq_rows(0)
    toks = list(range(80))
    digs = page_digests(toks, 16, "m")
    parent = root_key("m")
    for i, d in enumerate(digs):
        idx.insert("m", d, parent, b.plane, rows[i],
                   toks[16 * i:16 * (i + 1)], 16 * (i + 1))
        parent = d
    assert len(idx.entries) == 2 and idx.evictions == 3    # LRU capped
    assert arena.check_mirror()
    b.free_seq(0)
    idx.flush()
    assert arena.check_mirror() and acc.m_kv == pytest.approx(0.0)


# ------------------------------------------------------------ engine level
@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _run_seq(m, params, prompts, prefix_cache, max_new=6, s_max=64):
    """Submit prompts one at a time (drain between) so later prompts can hit
    prefixes indexed by earlier ones."""
    eng = Engine(m, params, MemoryAccountant(m_total=256e6), max_slots=2,
                 s_max=s_max, kv_backend="ref", prefix_cache=prefix_cache)
    out = {}
    for i, p in enumerate(prompts):
        eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new))
        for r in eng.drain():
            out[r.req_id] = r
    return eng, out


def test_engine_hit_parity_token_for_token(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(5)
    base = list(rng.integers(0, cfg.vocab, 40))
    prompts = [base,                         # indexes 2 full pages
               base[:32] + [3, 1, 4, 1, 5],  # hits both full pages
               base[:16] + [9] * 20]         # hits page 0 only
    eng_off, off = _run_seq(m, params, prompts, prefix_cache=None)
    eng_on, on = _run_seq(m, params, prompts, prefix_cache=True)
    assert eng_on._pc is not None
    assert {k: r.out for k, r in on.items()} == \
           {k: r.out for k, r in off.items()}
    assert on[1].prefill_avoided == 32 and on[2].prefill_avoided >= 16
    assert off[1].prefill_avoided == 0
    assert eng_on._pc.hits >= 2 and eng_on._pc.tokens_avoided >= 48
    assert eng_on.arena.pages_aliased >= 3
    assert eng_on.arena.check_mirror()


def test_engine_partial_page_cow_parity(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(8)
    base = list(rng.integers(0, cfg.vocab, 40))
    div = base[:22] + [int(t) + 1 for t in base[22:]]  # diverges mid-page 1
    prompts = [base, div]
    eng_off, off = _run_seq(m, params, prompts, prefix_cache=None)
    eng_on, on = _run_seq(m, params, prompts, prefix_cache=True)
    assert {k: r.out for k, r in on.items()} == \
           {k: r.out for k, r in off.items()}
    # page 0 aliased whole; page 1 aliased then copy-on-written at token 22
    assert on[1].prefill_avoided == 22
    assert eng_on._pc.partial_hits == 1
    assert eng_on._pc.cow_copies >= 1 and eng_on.arena.cow_copies >= 1
    assert eng_on.arena.check_mirror()


def test_engine_sleep_recovers_all_headroom(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(9)
    base = list(rng.integers(0, cfg.vocab, 40))
    eng, _ = _run_seq(m, params, [base, base[:32] + [1, 2, 3]],
                      prefix_cache=True)
    acc = eng.acc
    assert eng._pc.entries and acc.ctx.get("prefix-cache", 0) > 0
    eng.release_kv()
    assert not eng._pc.entries
    assert "prefix-cache" not in acc.ctx
    assert eng.arena.mapped_pages() == 0 and eng.arena.mapped_rows() == 0
    assert all(not p.refs for p in eng.arena.planes.values())
    assert acc.m_kv == pytest.approx(0.0)
    assert eng.arena.check_mirror() and acc.check_invariant()


def test_disabled_cache_changes_nothing(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab, 24))] * 2
    eng, out = _run_seq(m, params, prompts, prefix_cache=None)
    assert eng._pc is None
    assert eng.arena.prefix_index is None
    assert eng.arena.pages_aliased == 0
    assert all(r.prefill_avoided == 0 for r in out.values())
