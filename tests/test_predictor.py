"""Predictor stack: GBDT learning, metric correctness, the two-phase
Maestro-Pred pipeline + its baselines/ablations. Property-based companions
(isotonic monotonicity) live in test_properties.py, which skips itself when
hypothesis is unavailable."""
import numpy as np
import pytest

from repro.core.predictor import (GBDT, GBDTConfig, IsotonicCalibrator,
                                  LinearBaseline, MaestroPred,
                                  PredictorConfig, classification_metrics,
                                  regression_metrics)
from repro.data.tracegen import generate_trace, stratified_temporal_split

RNG = np.random.default_rng(0)


def test_gbdt_regression_learns():
    X = RNG.normal(size=(3000, 6)).astype(np.float32)
    y = 2 * X[:, 0] - np.abs(X[:, 1]) + 0.05 * RNG.normal(size=3000)
    m = GBDT(GBDTConfig(n_trees=60, max_leaves=15)).fit(
        X[:2400], y[:2400], X[2400:], y[2400:])
    r2 = regression_metrics(y[2400:], m.predict(X[2400:]))["r2"]
    assert r2 > 0.9


def test_gbdt_classifier_calibrated_range():
    X = RNG.normal(size=(2000, 4)).astype(np.float32)
    y = (X[:, 0] > 0).astype(float)
    m = GBDT(GBDTConfig(n_trees=40, max_leaves=7, objective="logloss")).fit(
        X[:1500], y[:1500], X[1500:], y[1500:])
    p = m.predict(X[1500:])
    assert np.all((p >= 0) & (p <= 1))
    assert classification_metrics(y[1500:], p)["auc"] > 0.95


def test_gbdt_early_stopping():
    X = RNG.normal(size=(800, 3)).astype(np.float32)
    y = RNG.normal(size=800)   # pure noise: must stop early
    m = GBDT(GBDTConfig(n_trees=200, early_stopping=5)).fit(
        X[:600], y[:600], X[600:], y[600:])
    assert len(m.trees) < 200


def test_isotonic_monotone_fixed_grid():
    """Deterministic spot-check of the property in test_properties.py."""
    rng = np.random.default_rng(0)
    scores = rng.uniform(0, 1, 100)
    labels = (scores + rng.normal(0, 0.3, 100) > 0.5).astype(float)
    iso = IsotonicCalibrator().fit(scores, labels)
    out = iso.transform(np.linspace(0, 1, 64))
    assert np.all(np.diff(out) >= -1e-9)
    assert np.all((out >= 0) & (out <= 1))


def test_classification_metrics_perfect_and_random():
    y = np.array([0, 0, 1, 1, 1, 0, 1, 0], float)
    perfect = classification_metrics(y, y * 0.98 + 0.01)
    assert perfect["auc"] == pytest.approx(1.0)
    assert perfect["acc"] == 1.0
    rnd = classification_metrics(y, np.full(8, 0.5))
    assert 0.4 <= rnd["auc"] <= 0.6


@pytest.fixture(scope="module")
def small_trace():
    jobs = generate_trace(250, rate=1.0, seed=3)
    return stratified_temporal_split(jobs)


def _fit_kwargs(train):
    return dict(
        observations=[s.obs for s in train],
        lengths=np.array([s.true_len for s in train], float),
        tool_labels=np.array([float(s.tool_call) for s in train]))


FAST = PredictorConfig(
    cls=GBDTConfig(objective="logloss", n_trees=30, max_leaves=7),
    reg=GBDTConfig(n_trees=40, max_leaves=15))


def test_maestro_pred_end_to_end(small_trace):
    train, test = small_trace
    mp = MaestroPred(FAST).fit(**_fit_kwargs(train))
    out = mp.predict([s.obs for s in test])
    assert np.all(out["length"] >= 1)
    assert np.all((out["p_tool"] >= 0) & (out["p_tool"] <= 1))
    m = regression_metrics([s.true_len for s in test], out["length"])
    lin = LinearBaseline().fit(**_fit_kwargs(train))
    ml = regression_metrics([s.true_len for s in test],
                            lin.predict([s.obs for s in test])["length"])
    assert m["mae"] < ml["mae"]          # beats prompt-length-only OLS

    # p_tool gates: stages with no tools available get exactly 0
    no_tools = [s.obs for s in test if s.obs.tools_available == 0]
    if no_tools:
        assert np.all(mp.predict(no_tools)["p_tool"] == 0.0)


def test_ablation_direction(small_trace):
    """w/o semantic features must not beat the full model (Table VII)."""
    train, test = small_trace
    full = MaestroPred(FAST).fit(**_fit_kwargs(train))
    import dataclasses
    no_sem = MaestroPred(dataclasses.replace(FAST, use_semantic=False)).fit(
        **_fit_kwargs(train))
    y = [s.true_len for s in test]
    mae_full = regression_metrics(
        y, full.predict([s.obs for s in test])["length"])["mae"]
    mae_nosem = regression_metrics(
        y, no_sem.predict([s.obs for s in test])["length"])["mae"]
    assert mae_full <= mae_nosem * 1.05
