"""Sharding rules, roofline HLO parsing, gradient compression, fault
tolerance components."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.fault import ElasticController, StragglerDetector
from repro.distributed.sharding import ShardingCtx, mesh_rules
from repro.launch.roofline import (_shape_bytes, ideal_bytes,
                                   parse_collectives, roofline_terms)
from repro.training.optimizer import OptConfig, adamw_init, compress_grads


def test_mesh_rules_single_and_multi():
    r1 = mesh_rules(None)
    assert r1 == {}

    class FakeMesh:
        axis_names = ("data", "model")
    r = mesh_rules(FakeMesh())
    assert r["fsdp"] == "data" and r["tp"] == "model"

    class FakeMesh3:
        axis_names = ("pod", "data", "model")
    r3 = mesh_rules(FakeMesh3())
    assert r3["fsdp"] == ("pod", "data")
    assert r3["batch"] == ("pod", "data")


def test_sharding_ctx_noop_without_mesh():
    ctx = ShardingCtx(None)
    x = jnp.ones((4, 4))
    assert ctx.cs(x, "batch", None) is x
    assert ctx.axis_size("tp") == 1


def test_shape_bytes_parse():
    assert _shape_bytes("bf16[16,256,4096]{2,1,0}") == 16 * 256 * 4096 * 2
    assert _shape_bytes("(f32[8,8]{1,0}, s32[4]{0})") == 8 * 8 * 4 + 4 * 4
    assert _shape_bytes("pred[]") == 1 or _shape_bytes("pred[]") == 0


def test_parse_collectives_synthetic_hlo():
    hlo = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %ag = f32[256,128]{1,0} all-gather(f32[16,128]{1,0} %x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar.1 = bf16[1024]{0} all-reduce(bf16[1024]{0} %y), replica_groups=[8,16]<=[128], to_apply=%add
  %cp = f32[64]{0} collective-permute(f32[64]{0} %z), source_target_pairs={{0,1}}
}
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 256 * 128 * 4
    assert out["all-gather"]["max_group"] == 4
    assert out["all-reduce"]["max_group"] == 16
    assert out["all-reduce"]["traffic"] == pytest.approx(
        2 * 1024 * 2 * 15 / 16)
    assert out["collective-permute"]["traffic"] == 64 * 4


def test_ideal_bytes_skips_fused_and_elementwise():
    hlo = """
%fused_computation.1 (param_0: f32[1024]) -> f32[1024] {
  %big = f32[999999]{0} multiply(f32[999999]{0} %a, f32[999999]{0} %b)
}
ENTRY %main (p0: f32[16]) -> f32[16] {
  %d = f32[128,128]{1,0} dot(f32[128,64]{1,0} %x, f32[64,128]{1,0} %w), lhs_contracting_dims={1}
  %e = f32[4096]{0} add(f32[4096]{0} %u, f32[4096]{0} %v)
}
"""
    b = ideal_bytes(hlo)
    expected = (128 * 128 + 128 * 64 + 64 * 128) * 4
    assert b == expected    # add + fused internals are free


def test_roofline_terms_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 3,
            "ideal_bytes": 819e9 * 2}
    colls = {"all-reduce": {"traffic": 50e9 * 0.5, "bytes": 1, "count": 1,
                            "max_group": 16}}
    t = roofline_terms(cost, colls, n_chips=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["bottleneck"] == "memory"


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_gradient_compression_error_feedback(mode):
    cfg = OptConfig(compression=mode)
    params = {"w": jnp.zeros((64,))}
    state = adamw_init(params, compression=mode)
    g = {"w": jnp.linspace(-1, 1, 64) * 1e-3}
    total = jnp.zeros((64,))
    comp_total = jnp.zeros((64,))
    for _ in range(50):
        cg, state = compress_grads(g, state, cfg)
        total = total + g["w"]
        comp_total = comp_total + cg["w"]
    # error feedback keeps the long-run average unbiased
    np.testing.assert_allclose(np.asarray(comp_total), np.asarray(total),
                               rtol=0.05, atol=1e-4)


def test_straggler_detector_flags_outlier():
    sd = StragglerDetector(z_thresh=2.0)
    for step in range(30):
        for n in range(8):
            sd.observe(n, 1.0 + (5.0 if n == 3 else 0.0)
                       + 0.01 * np.sin(step + n))
    assert sd.stragglers() == [3]
    assert sd.is_straggler(3, 6.0)
    assert not sd.is_straggler(0, 1.0)


def test_elastic_controller_plans():
    ec = ElasticController(model_axis=16)
    plan = ec.plan(512, failed=[1, 2, 3], ckpt_step=7)
    assert plan.mesh_shape[1] == 16
    assert plan.mesh_shape[0] * 16 <= 512 - 3
    assert plan.restore_step == 7
    assert ec.plan(16, failed=list(range(15)), ckpt_step=None) is None
