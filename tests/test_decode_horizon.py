"""On-device multi-token decode horizon (fused H decode iterations).

The parity contract is OUTPUT-LEVEL, inherited from the chunked-prefill PR:
per-request greedy token sequences from a horizon engine (H > 1) must equal
the one-token-per-sync engine (H = 1) exactly — prefix cache on and off, on
every zoo model with self-attention KV, including eos stops, s_max
truncation and pool-refusal backpressure. H = 1 is the construction default
and shares the exact pre-horizon code path, so these tests pin the horizon
against the engine's own unchanged baseline.

Satellites pinned here: evict/cancel mid-horizon discards un-emitted tokens
and leaks no KV pages (extends the chunked-prefill page-leak regression),
compile counts stay flat across horizon values, the horizon/sync counters
flow through NodeRuntime.kv_stats into gateway aggregation, and mixed
prefill+decode iterations fall back to one-token decode.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime.accounting import MemoryAccountant
from repro.models import build_model
from repro.serving.engine import Engine, Request

HORIZON_ZOO = ("qwen3-8b", "starcoder2-15b")   # self-attention KV models


@pytest.fixture(scope="module", params=HORIZON_ZOO)
def zoo_model(request):
    cfg = get_config(request.param).reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, cfg.vocab, p))
            for p in (3, 7, 12, 5, 9, 14)]


def _drain_all(m, params, prompts, *, horizon, prefix_cache=False,
               sequential=False, max_new=6, max_slots=3, s_max=64,
               eos=None, **kw):
    eng = Engine(m, params, MemoryAccountant(m_total=512e6),
                 max_slots=max_slots, s_max=s_max, kv_backend="ref",
                 prefix_cache=prefix_cache, decode_horizon=horizon, **kw)
    out = {}
    if sequential:
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new,
                               eos=eos))
            for r in eng.drain():
                out[r.req_id] = r
    else:
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new,
                               eos=eos))
        for r in eng.drain():
            out[r.req_id] = r
    return eng, out


def _outs(done):
    return {k: r.out for k, r in done.items()}


# ------------------------------------------------------- output-level parity
def test_horizon_matches_h1_every_zoo_model(zoo_model):
    cfg, m, params = zoo_model
    assert m.supports_decode_horizon
    prompts = _prompts(cfg)
    _, base = _drain_all(m, params, prompts, horizon=1, max_new=12)
    for h in (4, 8, 16):
        eng, got = _drain_all(m, params, prompts, horizon=h, max_new=12)
        assert eng.horizon == h
        assert _outs(got) == _outs(base), f"horizon={h}"
        assert eng.stat_horizon_steps > 0
        assert eng.arena.mapped_pages() == 0


def test_horizon_matches_h1_with_prefix_cache(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(5)
    base_p = list(rng.integers(0, cfg.vocab, 40))
    prompts = [base_p,                          # indexes 2 full pages
               base_p[:32] + [3, 1, 4, 1, 5],   # hits both full pages
               base_p[:16] + [9] * 20,          # hits page 0 only
               base_p[:20] + [7] * 11]          # partial-page COW hit
    _, base = _drain_all(m, params, prompts, horizon=1, sequential=True,
                         max_slots=2, max_new=8)
    for pc in (False, True):
        eng, got = _drain_all(m, params, prompts, horizon=8,
                              prefix_cache=pc, sequential=True,
                              max_slots=2, max_new=8)
        assert _outs(got) == _outs(base), pc
        if pc:   # horizon writes were privatised, never landed on shared rows
            assert [got[k].prefill_avoided for k in sorted(got)] == \
                   [0, 32, 16, 20]
        assert eng.arena.check_mirror()


def test_horizon_eos_and_smax_stops_match_h1(tiny):
    """Mid-horizon stops: a lane hitting eos or the s_max wall freezes on
    device and the un-emitted tail of its token block is discarded."""
    cfg, m, params = tiny
    prompts = _prompts(cfg)[:4]
    _, probe = _drain_all(m, params, prompts, horizon=1, max_new=10)
    eos = probe[0].out[3]          # a token known to appear mid-stream
    for kw in (dict(eos=eos), dict(s_max=20)):
        _, base = _drain_all(m, params, prompts, horizon=1, max_new=10, **kw)
        _, got = _drain_all(m, params, prompts, horizon=8, max_new=10, **kw)
        assert _outs(got) == _outs(base), kw
    assert any(len(r.out) < 10 for r in base.values())   # the wall was hit


def test_horizon_pool_backpressure_truncates_like_h1(tiny):
    """When the pool cannot pre-grant even one token the lane truncates —
    the same honest backpressure as the one-token path; partial grants cap
    the launch but the lane keeps retrying. Pool growth is refused outright
    after prefill, so both engines hit the wall at the same page boundary."""
    cfg, m, params = tiny
    prompts = [[1, 2, 3], [4, 5, 6, 7]]

    def run(h):
        eng = Engine(m, params, MemoryAccountant(m_total=512e6),
                     max_slots=2, s_max=256, kv_backend="ref",
                     decode_horizon=h)
        eng.pool._grow(4)                        # fixed page inventory...
        eng.pool._grow = lambda n: False         # ...and not one page more
        for i, p in enumerate(prompts):
            # pred_len=1 keeps the admission grant near prompt-size, so
            # decode must extend page coverage mid-stream
            eng.submit(Request(req_id=i, tokens=list(p), max_new=200,
                               pred_len=1))
        done = {r.req_id: r for r in eng.drain()}
        assert eng.arena.mapped_pages() == 0
        return done

    base, got = run(1), run(16)
    assert all(r.truncated for r in base.values())   # pool really refused
    assert all(len(r.out) < 200 for r in base.values())
    assert _outs(got) == _outs(base)
    assert {k: r.truncated for k, r in got.items()} == \
           {k: r.truncated for k, r in base.items()}


# ----------------------------------------------- preemption / page-leak
def test_evict_mid_horizon_frees_pages_and_replays_identically(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(3)
    prompt = list(rng.integers(0, cfg.vocab, 24))
    _, base = _drain_all(m, params, [prompt], horizon=8, max_new=12)
    acc = MemoryAccountant(m_total=512e6)
    eng = Engine(m, params, acc, max_slots=2, s_max=64, kv_backend="ref",
                 decode_horizon=8)
    eng.submit(Request(req_id=0, tokens=list(prompt), max_new=12))
    eng.step()             # prefill + first token + one horizon launch
    assert eng.stat_horizon_steps == 1
    assert 0 in eng.active and len(eng.active[0].out) > 1
    req = eng.evict(0)
    # un-emitted horizon tokens are gone WITH the emitted ones: boundary
    # preemption discards the partial output wholesale
    assert req is not None and req.out == []
    assert eng.arena.mapped_pages() == 0 and eng.arena.mapped_rows() == 0
    assert acc.m_kv == pytest.approx(0.0)
    assert eng.arena.check_mirror()
    # the requeued stage replays the identical greedy sequence
    eng.submit(req)
    done = {r.req_id: r for r in eng.drain()}
    assert _outs(done) == _outs(base)


def test_cancel_waiting_request_untouched_by_horizon(tiny):
    cfg, m, params = tiny
    eng = Engine(m, params, MemoryAccountant(m_total=512e6), max_slots=1,
                 s_max=64, kv_backend="ref", decode_horizon=8)
    eng.submit(Request(req_id=0, tokens=[1, 2, 3], max_new=20))
    eng.submit(Request(req_id=1, tokens=[4, 5, 6], max_new=4))
    eng.step(); eng.step()            # req 0 decoding via horizon; 1 waits
    assert eng.cancel(1).req_id == 1  # waiting -> no KV held, plain removal
    done = eng.drain()
    assert [r.req_id for r in done] == [0] and len(done[0].out) == 20


# ----------------------------------------------- compile + sync telemetry
def test_compile_count_flat_across_horizon(tiny):
    cfg, m, params = tiny
    prompts = _prompts(cfg)
    assert len({len(p) for p in prompts}) == 6
    engs = {h: _drain_all(m, params, prompts, horizon=h)[0]
            for h in (1, 4, 16)}
    compiles = {h: e.prefill_compiles for h, e in engs.items()}
    assert len(set(compiles.values())) == 1, compiles


def test_horizon_sync_counters(tiny):
    """One host sync per horizon launch: a single 17-token request (1 from
    prefill + 16 decoded) needs exactly ceil(16/8) = 2 launches at H=8,
    versus 16 decode syncs at H=1."""
    cfg, m, params = tiny
    prompts = [[1, 2, 3, 4, 5]]
    e1, _ = _drain_all(m, params, prompts, horizon=1, max_new=17)
    e8, _ = _drain_all(m, params, prompts, horizon=8, max_new=17)
    assert e1.stat_decode_syncs == 16 and e1.stat_horizon_steps == 0
    assert e8.stat_decode_syncs == 2 and e8.stat_horizon_steps == 2
    assert e8.stat_decode_tokens == e1.stat_decode_tokens == 16


def test_mixed_prefill_decode_iterations_fall_back_to_h1(tiny):
    """While any sequence is mid-chunked-prefill the iteration decodes one
    token per lane (fusion semantics untouched); pure-decode iterations
    resume horizon launches. Outputs stay identical throughout."""
    cfg, m, params = tiny
    prompts = _prompts(cfg)
    _, base = _drain_all(m, params, prompts, horizon=1, max_new=10)
    eng, got = _drain_all(m, params, prompts, horizon=8, max_new=10,
                          prefill_chunk_tokens=4)
    assert _outs(got) == _outs(base)
    assert eng.stat_fused_steps > 0       # mixed iterations happened...
    assert eng.stat_horizon_steps > 0     # ...and pure-decode ones too


def test_ssm_model_horizon_degrades_to_h1():
    """A model without pure self-attention KV cannot run the on-device
    horizon — the knob degrades to one-token decode instead of failing."""
    cfg = get_config("mamba2-2.7b").reduced()
    m = build_model(cfg)
    assert not m.supports_decode_horizon
    params = m.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg)[:2]
    _, base = _drain_all(m, params, prompts, horizon=1, max_new=4)
    eng, got = _drain_all(m, params, prompts, horizon=8, max_new=4)
    assert eng.horizon == 1 and eng.stat_horizon_steps == 0
    assert _outs(got) == _outs(base)


def test_node_kv_stats_exposes_horizon_counters(tiny):
    from repro.serving.node_runtime import NodeRuntime
    cfg, m, params = tiny
    host = jax.tree.map(np.asarray, params)
    node = NodeRuntime(0, 0, {cfg.name: m}, {cfg.name: host},
                       hbm_budget=1.2e9, max_slots=2, s_max=64,
                       decode_horizon=8)
    node.submit(cfg.name, Request(req_id=0, tokens=[1, 2, 3, 4, 5],
                                  max_new=9))
    for _ in range(30):
        node.step()
        if not node.has_work():
            break
    st = node.kv_stats()
    assert st["engine_horizon_steps"] == 1     # 8 decode tokens, one launch
    assert st["engine_decode_syncs"] == 1
    assert st["engine_decode_tokens"] == 8


def test_gateway_aggregates_syncs_per_token(tiny):
    """Fleet-level headline: host_syncs_per_token collapses toward 1/H and
    virtual-clock outputs stay identical to the H=1 fleet."""
    from repro.core.predictor.features import StageObservation
    from repro.serving.cluster import (ClusterSpec, LiveJob, LiveStage,
                                       NodeSpec, build_fleet)
    from repro.serving.gateway import ClusterGateway
    cfg, m, params = tiny
    zoo = {cfg.name: m}
    host = {cfg.name: jax.tree.map(np.asarray, params)}
    rtt = np.array([[0.001]])

    def obs(i):
        return StageObservation(app=0, role=0, position=0.0,
                                invocation_idx=i, tools_available=0,
                                cot=False, prompt_len=6, model_id=0,
                                text="s", src_cluster=0)

    def jobs():
        return [LiveJob(job_id=0, app="t", interactive=True, arrival_s=0.0,
                        stages=[LiveStage(stage_id=s, job_id=0, deps=[],
                                          obs=obs(s), interactive=True,
                                          tokens=[1, 2, 3 + s, 4, 5, 6],
                                          max_new=9) for s in range(3)])]

    def run(h):
        fleet = build_fleet(ClusterSpec(
            nodes=(NodeSpec(0, max_slots=2, decode_horizon=h),),
            rtt_s=rtt, model_names=(cfg.name,)), zoo=zoo, host=host)
        gw = ClusterGateway(fleet, rtt, policy="fcfs")
        metrics = gw.run(jobs())
        return metrics, {s: e.out_len for s, e in gw.telemetry.events.items()}

    m1, o1 = run(1)
    m8, o8 = run(8)
    assert o8 == o1
    assert m8.engine_horizon_steps > 0 and m1.engine_horizon_steps == 0
    assert m8.host_syncs_per_token <= 1 / 8
    assert m8.host_syncs_per_token < m1.host_syncs_per_token
