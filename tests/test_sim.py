"""Trace generation + discrete-event simulator integration."""
import numpy as np
import pytest

from repro.core.predictor import MaestroPred, PredictorConfig
from repro.core.predictor.gbdt import GBDTConfig
from repro.data.apps import APPS
from repro.data.tracegen import (flatten_stages, generate_trace,
                                 stratified_temporal_split)
from repro.core.sched.policies import (EDF, FCFS, BaselineLB, Maestro,
                                       MaestroNoPreempt, OracleSRTF)
from repro.sim.simulator import SimConfig, Simulator


def test_trace_structure():
    jobs = generate_trace(120, rate=1.0, seed=0)
    assert len(jobs) == 120
    stages = flatten_stages(jobs)
    sids = [s.stage_id for s in stages]
    assert len(sids) == len(set(sids))
    for j in jobs:
        ids = {s.stage_id for s in j.stages}
        for s in j.stages:
            for d in s.deps:
                assert d in ids and d < s.stage_id   # DAG, topological ids
    # arrivals increase
    arr = [j.arrival_s for j in jobs]
    assert all(a <= b for a, b in zip(arr, arr[1:]))


def test_trace_batch_ratio_knob():
    lo = generate_trace(400, batch_ratio=0.2, seed=1)
    hi = generate_trace(400, batch_ratio=0.8, seed=1)
    frac_lo = np.mean([not j.interactive for j in lo])
    frac_hi = np.mean([not j.interactive for j in hi])
    assert frac_lo < 0.35 and frac_hi > 0.65


def test_tool_stages_are_short():
    stages = flatten_stages(generate_trace(300, seed=2))
    tool = [s.true_len for s in stages if s.tool_call]
    free = [s.true_len for s in stages if not s.tool_call]
    assert np.median(tool) < np.median(free) / 2   # Observation-1 bimodality


def test_stratified_split_is_temporal():
    jobs = generate_trace(200, seed=3)
    train, test = stratified_temporal_split(jobs)
    assert len(train) + len(test) == len(flatten_stages(jobs))
    # within each stratum, every test record is newer than every train record
    import collections
    tr_g, te_g = collections.defaultdict(list), collections.defaultdict(list)
    for s in train:
        tr_g[(s.obs.role, s.tool_call, s.obs.cot)].append(s.stage_id)
    for s in test:
        te_g[(s.obs.role, s.tool_call, s.obs.cot)].append(s.stage_id)
    for g, te in te_g.items():
        if g in tr_g:
            assert min(te) > max(tr_g[g])


@pytest.fixture(scope="module")
def predictor():
    jobs = generate_trace(250, rate=1.0, seed=4)
    train, _ = stratified_temporal_split(jobs)
    cfg = PredictorConfig(
        cls=GBDTConfig(objective="logloss", n_trees=25, max_leaves=7),
        reg=GBDTConfig(n_trees=30, max_leaves=15))
    return MaestroPred(cfg).fit(
        [s.obs for s in train],
        np.array([s.true_len for s in train], float),
        np.array([float(s.tool_call) for s in train]))


@pytest.mark.parametrize("policy_cls", [FCFS, EDF, OracleSRTF])
def test_sim_completes_all_jobs(policy_cls):
    jobs = generate_trace(150, rate=1.0, seed=5)
    r = Simulator(jobs, policy_cls(), SimConfig()).run()
    assert r.finished_jobs == 150
    assert 0.0 <= r.slo_attainment <= 1.0


def test_sim_maestro_completes_and_accounts(predictor):
    jobs = generate_trace(150, rate=1.5, seed=6)
    sim = Simulator(jobs, Maestro(predictor), SimConfig())
    r = sim.run()
    assert r.finished_jobs == 150
    for n in sim.nodes:
        assert n.acc.check_invariant()
        assert not n.running            # all released


def test_sim_maestro_beats_fcfs_under_contention(predictor):
    cfg = SimConfig(nodes_per_cluster=(2, 1, 1))
    jobs_fn = lambda: generate_trace(250, rate=2.5, seed=7, batch_ratio=0.6)
    r_f = Simulator(jobs_fn(), FCFS(), cfg).run()
    r_m = Simulator(jobs_fn(), Maestro(predictor), cfg).run()
    assert r_m.slo_attainment > r_f.slo_attainment
    assert (r_m.interactive_queue_delay_s
            < r_f.interactive_queue_delay_s + 1e-9)


def test_app_mix_covers_table1():
    assert len(APPS) == 9
    assert sum(a.interactive for a in APPS) == 4   # 4 interactive, 5 batch
    assert abs(sum(a.weight for a in APPS) - 1.0) < 1e-6
