"""Pallas kernel validation: shape/dtype sweeps in interpret mode against the
pure-jnp ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chunk_prefill import chunk_prefill_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_chunk import ssd_chunk

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,hd", [
    (1, 128, 128, 4, 4, 64),     # MHA
    (2, 256, 256, 8, 2, 64),     # GQA 4x
    (1, 128, 256, 8, 1, 128),    # MQA, cross-length
    (2, 64, 64, 2, 2, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Sk, H, Hkv, hd, causal, dtype):
    if causal and Sq != Sk:
        pytest.skip("causal requires square for this sweep")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,Hkv,hd,page,slots", [
    (2, 8, 2, 64, 16, 8),
    (3, 4, 4, 32, 8, 4),
    (1, 16, 2, 128, 32, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, H, Hkv, hd, page, slots, dtype):
    n_pages = B * slots + 3
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), dtype)
    bt = jax.random.permutation(ks[3], n_pages)[:B * slots] \
        .reshape(B, slots).astype(jnp.int32)
    max_len = page * slots
    seq_lens = jax.random.randint(ks[4], (B,), 1, max_len + 1)
    out = paged_attention(q, kp, vp, bt, seq_lens, page_size=page,
                          interpret=True)
    exp = ref.paged_attention_ref(q, kp, vp, bt, seq_lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,Hkv,hd,page,slots", [
    (2, 8, 2, 64, 16, 8),
    (3, 4, 4, 32, 8, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_inline_splice_matches_scatter(B, H, Hkv, hd, page,
                                                       slots, dtype):
    """The decode-horizon read-your-own-write path: attending with the new
    token's K/V spliced inline (``k_new``/``v_new``) must be BITWISE equal
    to scattering it into the pages first and attending without the splice —
    for the ref oracle and the Pallas kernel alike. The page row under the
    write position holds garbage, proving the splice (not the page) is read.
    """
    n_pages = B * slots + 3
    ks = jax.random.split(KEY, 7)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), dtype)
    bt = jax.random.permutation(ks[3], n_pages)[:B * slots] \
        .reshape(B, slots).astype(jnp.int32)
    seq_lens = jax.random.randint(ks[4], (B,), 1, page * slots + 1)
    k_new = jax.random.normal(ks[5], (B, Hkv, hd), dtype)
    v_new = jax.random.normal(ks[6], (B, Hkv, hd), dtype)
    # scatter k_new/v_new at position seq_len - 1 (row, offset per batch)
    w = seq_lens - 1
    rows = bt[jnp.arange(B), w // page]
    offs = w % page
    kp_sc = kp.at[rows, offs].set(k_new)
    vp_sc = vp.at[rows, offs].set(v_new)
    exp_ref = ref.paged_attention_ref(q, kp_sc, vp_sc, bt, seq_lens)
    got_ref = ref.paged_attention_ref(q, kp, vp, bt, seq_lens,
                                      k_new=k_new, v_new=v_new)
    np.testing.assert_array_equal(np.asarray(got_ref, np.float32),
                                  np.asarray(exp_ref, np.float32))
    exp_pl = paged_attention(q, kp_sc, vp_sc, bt, seq_lens, page_size=page,
                             interpret=True)
    got_pl = paged_attention(q, kp, vp, bt, seq_lens, page_size=page,
                             interpret=True, k_new=k_new, v_new=v_new)
    np.testing.assert_array_equal(np.asarray(got_pl, np.float32),
                                  np.asarray(exp_pl, np.float32))
    np.testing.assert_allclose(np.asarray(got_pl, np.float32),
                               np.asarray(got_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,C,H,Hkv,hd,page,slots", [
    (2, 4, 4, 2, 8, 4, 4),       # GQA 2x, chunk spans pages
    (3, 8, 6, 2, 16, 8, 3),      # GQA 3x
    (1, 16, 2, 2, 32, 16, 2),    # MHA, chunk == page
    (2, 8, 8, 1, 64, 4, 6),      # MQA, chunk 2x page
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_prefill_attention_sweep(B, C, H, Hkv, hd, page, slots, dtype):
    """Chunked-prefill attention vs the jnp oracle: each sequence's chunk
    sits at a random absolute offset into its pages (earlier chunks below,
    causal within), exactly the mid-prompt state the engine drives."""
    n_pages = B * slots + 3
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, C, H, hd), dtype)
    kp = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (n_pages, page, Hkv, hd), dtype)
    bt = jax.random.permutation(ks[3], n_pages)[:B * slots] \
        .reshape(B, slots).astype(jnp.int32)
    p0 = jax.random.randint(ks[4], (B,), 0, slots * page - C + 1)
    pos = (p0[:, None] + jnp.arange(C)[None, :]).astype(jnp.int32)
    out = chunk_prefill_attention(q, kp, vp, bt, pos, page_size=page,
                                  interpret=True)
    exp = ref.chunk_prefill_attention_ref(q, kp, vp, bt, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_chunk_prefill_pad_rows_are_finite():
    """Pad rows (position repeated at 0) must produce finite garbage, not
    NaN — the engine discards them but NaN would poison donated pages."""
    B, C, H, Hkv, hd, page, slots = 2, 4, 4, 2, 8, 4, 3
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, C, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (10, page, Hkv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (10, page, Hkv, hd), jnp.float32)
    bt = jnp.ones((B, slots), jnp.int32)
    pos = jnp.zeros((B, C), jnp.int32)        # all-pad sequences
    out = chunk_prefill_attention(q, kp, vp, bt, pos, page_size=page,
                                  interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 64, 2, 16, 8, 16),
    (2, 96, 8, 64, 32, 32),     # non-pow2 seq / chunk interplay
])
def test_ssd_chunk_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, H, N), jnp.float32)
    out = ssd_chunk(x, dt, A, Bm, Cm, chunk=chunk, block_heads=2,
                    interpret=True)
    exp = ref.ssd_chunk_ref(x, dt, A, Bm, Cm)
    scale = float(np.max(np.abs(np.asarray(exp)))) + 1e-9
    err = np.max(np.abs(np.asarray(out) - np.asarray(exp))) / scale
    assert err < 5e-4, err


def test_ssd_chunk_equals_model_scan():
    """The Pallas kernel and the model's jnp chunked scan agree."""
    from repro.models.mamba2 import _ssd_chunk_scan
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, H, N), jnp.float32)
    out = ssd_chunk(x, dt, A, Bm, Cm, chunk=32, block_heads=4, interpret=True)
    exp, _ = _ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_ops_dispatch_ref_on_cpu():
    from repro.kernels import ops
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    a = ops.flash_attention(q, k, v)             # auto -> ref on CPU
    ops.set_mode("interpret")
    try:
        b = ops.flash_attention(q, k, v, bq=32, bk=32)
    finally:
        ops.set_mode(None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
