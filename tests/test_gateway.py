"""Live cross-cluster gateway: DAG completion ordering, rho-margin admission
rejection, boundary preemption of batch work by interactive arrivals,
cold-start-aware routing, and the refactored example's main path."""
import importlib.util
import pathlib

import numpy as np
import pytest

from _stubs import StubPred
from repro.core.predictor.features import StageObservation
from repro.serving.cluster import (ClusterSpec, LiveJob, LiveStage, NodeSpec,
                                   build_fleet, build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.telemetry import Telemetry

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])
ZOO_NAMES = ("qwen3-8b",)


@pytest.fixture(scope="module")
def zoo_host():
    return build_zoo(ZOO_NAMES, seed=1)


def _fleet(zoo_host, specs):
    zoo, host = zoo_host
    return build_fleet(ClusterSpec(nodes=tuple(specs), rtt_s=RTT,
                                   model_names=ZOO_NAMES), zoo=zoo, host=host)


def _obs(invocation=0, prompt_len=32, src_cluster=0):
    return StageObservation(app=0, role=0, position=0.0,
                            invocation_idx=invocation, tools_available=0,
                            cot=False, prompt_len=prompt_len, model_id=0,
                            text="live gateway stage", src_cluster=src_cluster)


def _stage(sid, jid, deps, interactive, max_new=6, tokens=None):
    return LiveStage(stage_id=sid, job_id=jid, deps=deps,
                     obs=_obs(invocation=sid % 8), interactive=interactive,
                     tokens=tokens or [1, 2, 3, 4, 5, 6], max_new=max_new)


def test_dag_completion_ordering(zoo_host):
    """Diamond DAG A -> (B, C) -> D completes respecting dependencies."""
    fleet = _fleet(zoo_host, [NodeSpec(0, max_slots=2), NodeSpec(0, max_slots=2)])
    job = LiveJob(job_id=0, app="t", interactive=True, arrival_s=0.0, stages=[
        _stage(0, 0, [], True),
        _stage(1, 0, [0], True),
        _stage(2, 0, [0], True),
        _stage(3, 0, [1, 2], True),
    ])
    gw = ClusterGateway(fleet, RTT, predictor=StubPred(), policy="maestro")
    m = gw.run([job])
    assert m.finished_jobs == 1 and m.finished_stages == 4
    ev = gw.telemetry.events
    assert ev[0].finish_t <= min(ev[1].dispatch_t, ev[2].dispatch_t)
    assert max(ev[1].finish_t, ev[2].finish_t) <= ev[3].dispatch_t
    for e in ev.values():       # lifecycle sanity on the virtual clock
        assert e.ready_t <= e.dispatch_t <= e.start_t <= e.finish_t
        assert e.out_len >= 1


def test_fcfs_policy_needs_no_predictor(zoo_host):
    fleet = _fleet(zoo_host, [NodeSpec(0)])
    job = LiveJob(0, "t", True, 0.0, [_stage(0, 0, [], True)])
    gw = ClusterGateway(fleet, RTT, policy="fcfs")
    m = gw.run([job])
    assert m.finished_jobs == 1
    with pytest.raises(ValueError):
        ClusterGateway(fleet, RTT, policy="maestro")     # no predictor


def test_oversized_prompt_truncated_at_dispatch(zoo_host):
    """A prompt no engine window can hold finishes truncated at DISPATCH
    time (no cold start, no transit wait) and its job keeps flowing."""
    fleet = _fleet(zoo_host, [NodeSpec(0, max_slots=2, s_max=64)])
    job = LiveJob(0, "t", True, 0.0, [   # t=0 arrival: hardest sentinel case
        _stage(0, 0, [], True, tokens=list(range(64))),   # > s_max - 1
        _stage(1, 0, [0], True),                          # dependent still runs
    ])
    gw = ClusterGateway(fleet, RTT, policy="fcfs")
    m = gw.run([job])
    assert m.truncated_stages == 1
    assert m.finished_jobs == 1 and m.finished_stages == 2
    assert gw.telemetry.events[0].out_len == 0            # truncated: no output
    assert gw.telemetry.events[1].out_len >= 1
    assert m.cold_starts <= 1                             # only the real stage


def test_admission_rejection_under_tight_hbm(zoo_host):
    """A stage whose rho-margined R_need can never fit is rejected (counted)
    and its job eventually dropped — no OOM, no livelock — and the drop
    clears every piece of readiness bookkeeping (no orphan stage ids in
    ready_since / the queue / the reject counters)."""
    fleet = _fleet(zoo_host, [NodeSpec(0, hbm_budget=96e6, max_slots=2)])
    giant = StubPred(length=2_000_000.0)     # R_kv_hat >> any node's HBM
    job = LiveJob(0, "t", True, 0.0, [
        _stage(0, 0, [], True),
        _stage(1, 0, [0], True),             # downstream, never becomes ready
    ])
    gw = ClusterGateway(fleet, RTT, predictor=giant, policy="maestro",
                        cfg=GatewayConfig(reject_limit=5))
    m = gw.run([job], max_ticks=500)
    assert m.admission_rejections > 0
    assert m.dropped_jobs == 1 and m.finished_jobs == 0
    assert gw.tick < 500                     # terminated by the drop, not cap
    for sid in (0, 1):                       # _drop_job left no orphans
        assert sid not in gw.ready_t
        assert gw.ready_since(sid) == float("inf")
        assert sid not in gw._queued and sid not in gw._rejects


def test_boundary_preemption_by_interactive_arrival(zoo_host):
    """A long batch stage holding the only slot is evicted at an engine-step
    boundary when an interactive stage arrives; both eventually finish."""
    fleet = _fleet(zoo_host, [NodeSpec(0, max_slots=1)])
    batch = LiveJob(0, "b", False, 0.0,
                    [_stage(0, 0, [], False, max_new=40)])
    inter = LiveJob(1, "i", True, 0.3,
                    [_stage(1, 1, [], True, max_new=5)])
    gw = ClusterGateway(fleet, RTT, predictor=StubPred(), policy="maestro")
    m = gw.run([batch, inter])
    assert m.preemptions >= 1
    assert gw.telemetry.events[0].preemptions >= 1       # the batch stage
    assert m.finished_jobs == 2                          # victim re-ran
    ev = gw.telemetry.events
    assert ev[1].finish_t < ev[0].finish_t               # interactive first
    assert ev[0].out_len == 40                           # full restart output


def test_cold_start_aware_routing_prefers_warm_node(zoo_host):
    """Fitness routing (T_ready = T_q + T_act) picks the node whose model is
    already resident over an identical cold node."""
    fleet = _fleet(zoo_host, [NodeSpec(0), NodeSpec(0)])
    fleet[1].activate(ZOO_NAMES[0])          # warm node 1
    job = LiveJob(0, "t", True, 0.0, [_stage(0, 0, [], True)])
    gw = ClusterGateway(fleet, RTT, predictor=StubPred(), policy="maestro")
    m = gw.run([job])
    assert m.finished_jobs == 1
    assert gw.telemetry.events[0].node_id == 1
    assert gw.telemetry.events[0].t_act_s < 0.01
    assert m.cold_starts == 0


def test_trace_adapter_and_multicluster_run(zoo_host):
    """End-to-end: generated multi-agent trace -> live jobs -> gateway run
    across two clusters, all DAGs completing with dependency order intact."""
    from repro.data.tracegen import generate_trace
    fleet = _fleet(zoo_host, [NodeSpec(0, max_slots=2),
                              NodeSpec(1, max_slots=2)])
    jobs = jobs_from_trace(generate_trace(3, rate=2.0, seed=5),
                           n_clusters=2, prompt_cap=8, gen_cap=8, seed=2)
    gw = ClusterGateway(fleet, RTT, predictor=StubPred(), policy="maestro")
    m = gw.run(jobs)
    assert m.finished_jobs == len(jobs)
    ev = gw.telemetry.events
    for j in jobs:
        for s in j.stages:
            for d in s.deps:
                assert ev[d].finish_t <= ev[s.stage_id].dispatch_t
    assert m.generated_tokens > 0
    assert np.isfinite(m.min_headroom_bytes)


def test_example_main_smoke():
    """The refactored example driver completes on reduced configs."""
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "serve_multi_agent.py")
    spec = importlib.util.spec_from_file_location("serve_multi_agent", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    metrics = mod.main(n_jobs=2, train_jobs=40, policy="maestro")
    assert metrics.finished_jobs == 2
    assert metrics.dropped_jobs == 0
