"""Checkpoint: atomic roundtrip, async save, pruning, elastic restore."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import latest_step, prune, restore, save


@pytest.fixture
def tree():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                       "step": jnp.zeros((), jnp.int32)}}


def test_roundtrip(tmp_path, tree):
    save(str(tmp_path), tree, step=3, extra={"loss": 0.5})
    out, extra = restore(str(tmp_path), tree)
    assert extra == {"loss": 0.5}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path, tree):
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), tree, step=s)
    assert latest_step(str(tmp_path)) == 5
    prune(str(tmp_path), keep=2)
    assert latest_step(str(tmp_path)) == 5
    out, _ = restore(str(tmp_path), tree, step=4)
    assert out is not None
    with pytest.raises(Exception):
        restore(str(tmp_path), tree, step=1)   # pruned


def test_async_save(tmp_path, tree):
    t = save(str(tmp_path), tree, step=9, async_=True)
    assert isinstance(t, threading.Thread)
    t.join(timeout=30)
    out, _ = restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


def test_no_partial_checkpoint_visible(tmp_path, tree):
    """tmp dirs never count as checkpoints (atomic publish)."""
    save(str(tmp_path), tree, step=1)
    (tmp_path / ".tmp_step_00000002").mkdir()
    assert latest_step(str(tmp_path)) == 1


def test_elastic_restore_dtype_cast(tmp_path, tree):
    """Restore casts to the target tree's dtypes (e.g. bf16 params from an
    f32 checkpoint after a precision change)."""
    save(str(tmp_path), tree, step=1)
    like = jax.tree.map(lambda x: x.astype(jnp.bfloat16)
                        if x.dtype == jnp.float32 else x, tree)
    out, _ = restore(str(tmp_path), like)
    assert out["w"].dtype == jnp.bfloat16
