"""Clock plane: VirtualClock event-heap ordering + determinism, WallClock
monotonicity, clock-enforced run deadlines (typed RunDeadlineExceeded),
seconds-denominated config shims, and virtual-vs-wall completion parity on
both node backends."""
import warnings

import numpy as np
import pytest

from repro.data.tracegen import generate_trace
from repro.serving.clock import (RunDeadlineExceeded, VirtualClock,
                                 WallClock, make_clock)
from repro.serving.cluster import (ClusterSpec, LiveJob, LiveStage, NodeSpec,
                                   build_fleet, build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])
ZOO_NAMES = ("qwen3-8b",)


# ---------------------------------------------------------------- VirtualClock

def test_virtual_clock_ticks_and_now():
    c = VirtualClock(tick_s=0.05)
    assert c.now() == 0.0
    for _ in range(8):
        c.advance()
    assert c.tick == 8
    assert c.now() == pytest.approx(8 * 0.05)


def test_virtual_event_heap_schedule_order_within_tick():
    """Two events due in the same tick release in SCHEDULE order even when
    their release times invert — this reproduces the pre-clock-plane
    gateway, which scanned its in-flight dict in insertion order, so it is
    what keeps virtual runs bit-identical."""
    c = VirtualClock(tick_s=0.05)
    c.call_at(0.12, "scheduled-first")       # due later within the tick
    c.call_at(0.11, "scheduled-second")      # due earlier within the tick
    assert c.pop_due() == []                 # t = 0: nothing due
    for _ in range(3):                       # t = 0.15: both due
        c.advance()
    assert c.pop_due() == ["scheduled-first", "scheduled-second"]
    assert c.pop_due() == []                 # events release exactly once


def test_virtual_event_due_epsilon():
    """An event AT a tick boundary releases on that tick (same 1e-9 slack
    the old per-tick submit_at scan used)."""
    c = VirtualClock(tick_s=0.05)
    c.call_at(1 * 0.05, "x")
    c.advance()
    assert c.pop_due() == ["x"]


def test_virtual_event_heap_determinism():
    def run():
        c = VirtualClock(tick_s=0.05)
        for i in range(20):
            c.call_at((i * 7 % 13) * 0.03, i)
        out = []
        for _ in range(15):
            out.append(tuple(c.pop_due()))
            c.advance()
        return out
    a, b = run(), run()
    assert a == b                                      # reproducible
    assert sorted(x for t in a for x in t) == list(range(20))  # all, once


def test_virtual_deadline_seconds_and_ticks():
    c = VirtualClock(tick_s=0.05)
    assert not c.expired()                   # no deadline: runs forever
    c.set_deadline(0.25)                     # = 5 ticks
    for _ in range(5):
        assert not c.expired()
        c.advance()
    assert c.expired() and c.deadline_s == pytest.approx(0.25)
    c2 = VirtualClock(tick_s=0.05)
    c2.set_deadline_ticks(3)                 # exact legacy max_ticks cap
    for _ in range(3):
        assert not c2.expired()
        c2.advance()
    assert c2.expired()


def test_virtual_cadence_matches_tick_modulus():
    c = VirtualClock(tick_s=0.05)
    cad = c.cadence(8 * 0.05)                # the old refresh_every=8
    fired = []
    for t in range(20):
        fired.append(cad.due())
        c.advance()
    assert fired == [(t % 8 == 0) for t in range(20)]


# ------------------------------------------------------------------- WallClock

def _fake_wall():
    t = [0.0]
    clock = WallClock(time_fn=lambda: t[0],
                      sleep_fn=lambda s: t.__setitem__(0, t[0] + s))
    return clock, t


def test_wall_clock_monotonic_real_time():
    c = WallClock()
    samples = [c.now() for _ in range(100)]
    assert all(b >= a for a, b in zip(samples, samples[1:]))
    assert samples[0] >= 0.0


def test_wall_clock_events_release_on_time():
    c, t = _fake_wall()
    c.call_at(0.010, "early")
    c.call_at(0.030, "late")
    assert c.pop_due() == []                 # t=0: nothing due yet
    c.advance(until=0.02)                    # sleeps to 0.02
    assert c.now() == pytest.approx(0.02)
    assert c.pop_due() == ["early"]          # released, not-before its time
    c.advance(until=0.05)
    assert c.pop_due() == ["late"]


def test_wall_clock_sleep_is_capped():
    c, t = _fake_wall()
    c.advance(until=10.0)                    # far wake-up: one capped sleep
    assert 0.0 < c.now() <= 0.2
    c2, _ = _fake_wall()
    before = c2.now()
    c2.advance(until=None)                   # free-run pass: no sleep
    assert c2.now() == before


def test_wall_clock_deadline():
    c, t = _fake_wall()
    c.set_deadline(1.0)
    assert not c.expired()
    t[0] = 1.2
    assert c.expired() and c.deadline_s == 1.0


def test_wall_clock_restart_rebases_pending_events():
    """restart() re-zeros the epoch; events still pending (stages left in
    transit when a prior run hit its deadline) keep their REMAINING delay
    instead of crashing or releasing at stale absolute times."""
    c, t = _fake_wall()
    c.call_at(5.0, "pending")            # due 5s from the old epoch
    t[0] = 3.0                           # 2s of delay remain
    c.restart()
    assert c.now() == 0.0
    assert c.pop_due() == []             # not due yet on the new epoch
    t[0] = 3.0 + 2.5                     # 2.5s after restart
    assert c.pop_due() == ["pending"]    # released after its remaining 2s


def test_wall_cadence_fires_on_period():
    c, t = _fake_wall()
    cad = c.cadence(0.5)
    assert cad.due()                         # first check fires (tick-0 law)
    assert not cad.due()
    t[0] = 0.6
    assert cad.due() and not cad.due()


def test_make_clock_rejects_unknown_mode():
    assert isinstance(make_clock("virtual", 0.05), VirtualClock)
    assert isinstance(make_clock("wall", 0.05), WallClock)
    with pytest.raises(ValueError, match="clock"):
        make_clock("lamport", 0.05)
    with pytest.raises(ValueError, match="clock"):
        ClusterGateway([], RTT, policy="fcfs",
                       cfg=GatewayConfig(clock="lamport"))


# ------------------------------------------------- config shims + run deadline

def test_config_seconds_shims_and_deprecation():
    # defaults: the legacy tick values expressed in seconds
    assert GatewayConfig().resolved_seconds() == \
        pytest.approx((0.1, 0.5, 0.4))
    # overriding a deprecated tick field still works, with a warning
    with pytest.warns(DeprecationWarning, match="preempt_gain_ticks"):
        gain, _, _ = GatewayConfig(
            preempt_gain_ticks=4.0).resolved_seconds()
    assert gain == pytest.approx(0.2)
    with pytest.warns(DeprecationWarning, match="refresh_every"):
        _, _, refresh = GatewayConfig(refresh_every=4).resolved_seconds()
    assert refresh == pytest.approx(0.2)
    # seconds-denominated fields win, silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        gain, cool, refresh = GatewayConfig(
            preempt_gain_s=0.3, preempt_cooldown_s=0.9,
            refresh_every_s=1.0).resolved_seconds()
    assert (gain, cool, refresh) == (0.3, 0.9, 1.0)


@pytest.fixture(scope="module")
def zoo_host():
    return build_zoo(ZOO_NAMES, seed=1)


def _inproc_fleet(zoo_host, specs):
    zoo, host = zoo_host
    return build_fleet(ClusterSpec(nodes=tuple(specs), rtt_s=RTT,
                                   model_names=ZOO_NAMES), zoo=zoo, host=host)


def test_run_deadline_exceeded_is_typed(zoo_host):
    """A run cut short by max_run_s reports a typed RunDeadlineExceeded in
    its metrics (instead of the old silent max_ticks truncation)."""
    from repro.core.predictor.features import StageObservation
    obs = StageObservation(app=0, role=0, position=0.0, invocation_idx=0,
                           tools_available=0, cot=False, prompt_len=32,
                           model_id=0, text="s", src_cluster=0)
    job = LiveJob(0, "t", True, 0.0, [
        LiveStage(stage_id=0, job_id=0, deps=[], obs=obs, interactive=True,
                  tokens=[1, 2, 3, 4], max_new=40)])
    fleet = _inproc_fleet(zoo_host, [NodeSpec(0)])
    gw = ClusterGateway(fleet, RTT, policy="fcfs",
                        cfg=GatewayConfig(max_run_s=0.2))   # 4 ticks: hopeless
    m = gw.run([job])
    assert m.run_outcome == "deadline_exceeded"
    assert isinstance(m.run_deadline, RunDeadlineExceeded)
    assert m.run_deadline.max_run_s == pytest.approx(0.2)
    assert m.run_deadline.unfinished_jobs == 1
    assert m.finished_jobs == 0
    row = m.row()                            # JSON-able nested outcome
    assert row["run_deadline"]["unfinished_jobs"] == 1
    # a completed run stays "completed" with no deadline record
    fleet2 = _inproc_fleet(zoo_host, [NodeSpec(0)])
    gw2 = ClusterGateway(fleet2, RTT, policy="fcfs")
    m2 = gw2.run([LiveJob(1, "t", True, 0.0, [
        LiveStage(stage_id=1, job_id=1, deps=[], obs=obs, interactive=True,
                  tokens=[1, 2, 3], max_new=4)])])
    assert m2.run_outcome == "completed" and m2.run_deadline is None


def test_worker_xla_flags_injection():
    """A worker spawned with WorkerSpec.xla_flags applies them before its
    XLA client forms (the wall-fleet threading knob) and still serves."""
    from repro.serving.engine import Request
    from repro.serving.worker import NodeHandle, WorkerSpec
    h = NodeHandle(WorkerSpec(
        node_id=3, cluster_id=0, model_names=ZOO_NAMES, max_slots=2,
        s_max=32, xla_flags="--xla_force_host_platform_device_count=1"))
    try:
        h.wait_ready()
        h.submit(ZOO_NAMES[0], Request(req_id=1, tokens=[1, 2, 3],
                                       max_new=3))
        out = {}
        for _ in range(30):
            for _, reqs in h.step().items():
                for r in reqs:
                    out[r.req_id] = r
            if out:
                break
        assert len(out[1].out) == 3
    finally:
        h.close()


# --------------------------------------------------- virtual-vs-wall parity

def _trace_jobs():
    return jobs_from_trace(generate_trace(2, rate=2.0, seed=5),
                           n_clusters=2, prompt_cap=8, gen_cap=6, seed=2)


def _completions(gw):
    ev = gw.telemetry.events
    done = {sid for sid, e in ev.items() if e.finish_t > 0}
    return done, {sid: ev[sid].out_len for sid in done}


def test_virtual_vs_wall_parity_both_backends(zoo_host):
    """The clock changes WHEN things happen, never WHAT completes: a small
    trace served under (virtual, inproc), (wall, inproc) and (wall,
    process) finishes the identical stage set with identical per-stage
    token counts (ordering-tolerant — wall timing is machine-dependent)."""
    specs = [NodeSpec(0, max_slots=2), NodeSpec(1, max_slots=2)]
    results = {}
    for clock, backend in (("virtual", "inproc"), ("wall", "inproc"),
                           ("wall", "process")):
        if backend == "process":
            fleet = build_fleet(ClusterSpec(nodes=tuple(specs), rtt_s=RTT,
                                            model_names=ZOO_NAMES),
                                backend="process")
        else:
            fleet = _inproc_fleet(zoo_host, specs)
        try:
            gw = ClusterGateway(
                fleet, RTT, policy="fcfs",
                cfg=GatewayConfig(clock=clock, node_backend=backend,
                                  max_run_s=None if clock == "virtual"
                                  else 300.0))
            m = gw.run(_trace_jobs())
            assert m.run_outcome == "completed", (clock, backend)
            assert m.clock == clock
            results[(clock, backend)] = _completions(gw)
        finally:
            close_fleet(fleet)
    ref_done, ref_tokens = results[("virtual", "inproc")]
    assert len(ref_done) > 0
    for key, (done, tokens) in results.items():
        assert done == ref_done, key         # identical completion SET
        assert tokens == ref_tokens, key     # identical per-stage tokens
