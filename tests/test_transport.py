"""Socket transport plane: framing unit tests, the socket node backend's
bit-identical virtual-clock parity, and the standalone worker entry point.

Parity bar (same as the process backend's in test_worker.py): under the
deterministic virtual clock a localhost socket fleet must produce the SAME
completion sets and the SAME metrics as the in-process fleet — and since
test_worker.py pins process == inproc, socket == inproc pins all three
backends to one outcome."""
import os
import re
import socket
import struct
import subprocess
import sys

import pytest

from repro.data.tracegen import generate_trace
from repro.serving import transport
from repro.serving.cluster import (ClusterSpec, NodeSpec, jobs_from_trace,
                                   worker_specs)
from repro.serving.engine import Request
from repro.serving.transport import (FRAME_VERSION, MAGIC, FrameTransport,
                                     ProtocolVersionError, TransportError,
                                     parse_address)
from repro.serving.worker import SocketNodeHandle
from test_worker import RTT, ZOO_NAMES, _assert_parity, _run


def _pair():
    a, b = socket.socketpair()
    return FrameTransport(a), FrameTransport(b)


# ---------------------------------------------------------------- framing

def test_frame_roundtrip_and_counters():
    a, b = _pair()
    try:
        payloads = [("step", ()), {"x": [1, 2, 3]}, None,
                    Request(req_id=9, tokens=[1, 2], max_new=4)]
        for obj in payloads:
            a.send(obj)
        for obj in payloads:
            got = b.recv()
            if isinstance(obj, Request):
                assert got.req_id == obj.req_id and got.tokens == obj.tokens
            else:
                assert got == obj
        assert a.frames_sent == b.frames_recv == len(payloads)
        assert a.bytes_sent == b.bytes_recv > 0
        assert a.bytes_recv == b.bytes_sent == 0
    finally:
        a.close()
        b.close()


def test_poll_semantics():
    a, b = _pair()
    try:
        assert not b.poll(0.0)
        a.send("hello")
        assert b.poll(1.0)
        assert b.recv() == "hello"
        assert not b.poll(0.0)
    finally:
        a.close()
        b.close()


def test_eof_on_peer_close():
    a, b = _pair()
    a.close()
    try:
        assert b.poll(1.0)               # EOF counts as readable
        with pytest.raises(EOFError):
            b.recv()
    finally:
        b.close()


def test_bad_magic_rejected():
    a, b = socket.socketpair()
    t = FrameTransport(b)
    try:
        a.sendall(b"GARBAGE_" + b"\x00" * 8)
        with pytest.raises(TransportError, match="magic"):
            t.recv()
    finally:
        a.close()
        t.close()


def test_version_mismatch_is_typed():
    a, b = socket.socketpair()
    t = FrameTransport(b)
    try:
        hdr = struct.Struct("!4sBxxxI").pack(MAGIC, FRAME_VERSION + 1, 0)
        a.sendall(hdr)
        with pytest.raises(ProtocolVersionError, match="version"):
            t.recv()
    finally:
        a.close()
        t.close()


def test_oversized_frame_rejected():
    a, b = socket.socketpair()
    t = FrameTransport(b)
    try:
        hdr = struct.Struct("!4sBxxxI").pack(MAGIC, FRAME_VERSION,
                                             transport.MAX_FRAME_BYTES + 1)
        a.sendall(hdr)
        with pytest.raises(TransportError, match="length"):
            t.recv()
    finally:
        a.close()
        t.close()


def test_close_idempotent():
    a, b = _pair()
    b.close()
    a.close()
    a.close()                            # second close is a no-op


def test_parse_address():
    assert parse_address("127.0.0.1:9000") == ("127.0.0.1", 9000)
    assert parse_address("host.example:0") == ("host.example", 0)
    for bad in ("nohost", ":123", "h:", "h:port"):
        with pytest.raises(ValueError):
            parse_address(bad)


# ------------------------------------------------- socket backend parity

def test_socket_backend_virtual_parity():
    """Localhost socket fleet under the virtual clock: identical completion
    sets and bit-identical metrics vs the in-process fleet, with real bytes
    on the wire (transport counters > 0)."""
    specs = [NodeSpec(0, max_slots=2), NodeSpec(1, max_slots=2)]

    def jobs():
        return jobs_from_trace(generate_trace(3, rate=2.0, seed=5),
                               n_clusters=2, prompt_cap=8, gen_cap=8, seed=2)

    m_in, ev_in = _run("inproc", jobs, specs)
    m_sock, ev_sock = _run("socket", jobs, specs)
    assert m_sock.node_backend == "socket"
    _assert_parity(m_in, ev_in, m_sock, ev_sock)
    assert m_sock.rpc_bytes_sent > 0 and m_sock.rpc_bytes_recv > 0
    assert set(m_sock.worker_stats) == {0, 1}
    for stats in m_sock.worker_stats.values():
        assert stats["bytes_sent"] > 0 and stats["bytes_recv"] > 0
    assert m_in.rpc_bytes_sent == 0


# --------------------------------------------- standalone worker process

def test_standalone_worker_cli():
    """`python -m repro.serving.worker --listen` + SocketNodeHandle.connect:
    the remote-host deployment path, exercised over localhost."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH"),
                    os.path.join(os.path.dirname(__file__), "..", "src"))
        if p)
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serving.worker",
         "--listen", "127.0.0.1:0", "--once"],
        stdout=subprocess.PIPE, text=True, env=env)
    h = None
    try:
        line = proc.stdout.readline()
        m = re.search(r"listening on ([\d.]+):(\d+)", line)
        assert m, f"no listen banner in {line!r}"
        spec = worker_specs(ClusterSpec(nodes=(NodeSpec(0),), rtt_s=RTT,
                                        model_names=ZOO_NAMES))[0]
        h = SocketNodeHandle.connect((m.group(1), int(m.group(2))), spec)
        h.wait_ready()
        assert h.proc is None                       # no local child
        assert set(h.profiles) == set(ZOO_NAMES)
        assert h.signal().node_id == 0
        h.submit(ZOO_NAMES[0], Request(req_id=1, tokens=[1, 2, 3],
                                       max_new=2))
        done = {}
        for _ in range(20):
            for _, reqs in h.step().items():
                done.update((r.req_id, r) for r in reqs)
            if done:
                break
        assert len(done[1].out) == 2
        assert h.worker_stats()["bytes_sent"] > 0
    finally:
        if h is not None:
            h.close()
            h.close()                               # idempotent
        proc.wait(timeout=30)
    assert proc.returncode == 0
