"""Deterministic-workload suite for the production-traffic generator.

Two contracts: (1) every new arrival process / demand / length distribution
is byte-reproducible — the same seed yields a byte-identical trace, hashed
over every field of every job and stage; (2) the legacy generators are
FROZEN — ``generate_trace``/``generate_team_trace`` outputs for existing
seeds are pinned by golden fingerprints, so no tracegen growth can ever
silently shift the traces the sim/gateway benchmarks and calibration
suites are built on."""
import numpy as np
import pytest

from repro.data.tracegen import (ARRIVALS, DEMANDS, LENGTHS, TAIL_SCENARIOS,
                                 DiurnalArrivals, MarkovModulatedArrivals,
                                 ParetoLengths, PoissonArrivals, ZipfDemand,
                                 generate_team_trace, generate_trace,
                                 generate_workload, make_arrival,
                                 scenario_workload, workload_fingerprint)

# golden fingerprints of the LEGACY generators at in-use seeds (test_sim,
# benchmarks/common.get_trace, calibration suites). If one of these moves,
# a change altered existing traces — that is a regression, not a refresh.
LEGACY_GOLDEN = {
    ("trace", 64, 0): "7b5d13aa608a24ddff804898334c9938",
    ("trace", 48, 13): "2a59de01bae5fba85d7522d8809b353d",
    ("team", 24, 0): "4b62ed5b23751f2da10bf93471f4d8b3",
    ("team", 16, 5): "837f9027ff527bb587e795c95b57183a",
}


def test_legacy_generate_trace_is_frozen():
    assert workload_fingerprint(generate_trace(64, seed=0)) \
        == LEGACY_GOLDEN[("trace", 64, 0)]
    assert workload_fingerprint(
        generate_trace(48, rate=2.0, batch_ratio=0.6, seed=13)) \
        == LEGACY_GOLDEN[("trace", 48, 13)]


def test_legacy_generate_team_trace_is_frozen():
    assert workload_fingerprint(generate_team_trace(24, seed=0)) \
        == LEGACY_GOLDEN[("team", 24, 0)]
    assert workload_fingerprint(
        generate_team_trace(16, seed=5, n_teams=2)) \
        == LEGACY_GOLDEN[("team", 16, 5)]


@pytest.mark.parametrize("arrival", [
    "poisson",
    ("poisson", dict(rate=3.0)),
    ("diurnal", dict(base_rate=0.5, peak_rate=5.0, period_s=60.0)),
    ("mmpp", dict(rates=(0.5, 8.0), dwell_s=(20.0, 5.0))),
])
@pytest.mark.parametrize("demand", [None, ("zipf", dict(alpha=1.2)),
                                    "uniform"])
def test_same_seed_byte_identical(arrival, demand):
    a = generate_workload(40, arrival=arrival, demand=demand,
                          lengths="pareto", seed=7)
    b = generate_workload(40, arrival=arrival, demand=demand,
                          lengths="pareto", seed=7)
    assert workload_fingerprint(a) == workload_fingerprint(b)
    # and a different seed actually changes the trace
    c = generate_workload(40, arrival=arrival, demand=demand,
                          lengths="pareto", seed=8)
    assert workload_fingerprint(a) != workload_fingerprint(c)


def test_arrivals_sorted_and_positive():
    for spec in ("poisson", ("diurnal", {}), ("mmpp", {})):
        jobs = generate_workload(60, arrival=spec, seed=3)
        ts = [j.arrival_s for j in jobs]
        assert ts == sorted(ts)
        assert ts[0] > 0
        assert all(np.isfinite(ts))


def test_knob_independence():
    """Changing one knob (the arrival process) must not reshuffle the
    stage bodies: jobs keep identical stage structure because arrivals and
    bodies draw from independent seeded streams."""
    a = generate_workload(30, arrival=("poisson", dict(rate=1.0)), seed=5)
    b = generate_workload(30, arrival=("poisson", dict(rate=9.0)), seed=5)
    for ja, jb in zip(a, b):
        assert ja.app == jb.app
        assert [s.true_len for s in ja.stages] \
            == [s.true_len for s in jb.stages]
        assert [s.obs.model_id for s in ja.stages] \
            == [s.obs.model_id for s in jb.stages]
        assert ja.arrival_s != jb.arrival_s


def test_zipf_demand_spans_full_zoo_and_is_skewed():
    jobs = generate_workload(300, demand=("zipf", dict(alpha=1.2)), seed=0)
    counts = np.zeros(10, int)
    for j in jobs:
        for s in j.stages:
            counts[s.obs.model_id] += 1
    assert (counts > 0).all()          # vision/MoE/SSM/whisper all hit
    assert counts[0] > 3 * counts[9]   # genuinely heavy-tailed
    # rank probabilities follow (k+1)^-alpha exactly
    p = ZipfDemand(alpha=1.2, n_models=10).probs()
    assert p.shape == (10,) and abs(p.sum() - 1.0) < 1e-12
    assert (np.diff(p) < 0).all()


def test_pareto_lengths_clipped_and_heavy():
    rng = np.random.default_rng(0)
    pl = ParetoLengths()
    outs = np.array([pl.output_len(rng) for _ in range(4000)])
    prompts = np.array([pl.prompt_len(rng) for _ in range(4000)])
    assert outs.min() >= 4 and outs.max() <= pl.out_cap
    assert prompts.min() >= 16 and prompts.max() <= pl.prompt_cap
    # heavy tail: the p99.9 dwarfs the median
    assert np.percentile(outs, 99.9) > 8 * np.median(outs)


def test_diurnal_rate_profile_bounds():
    d = DiurnalArrivals(base_rate=1.0, peak_rate=5.0, period_s=50.0)
    ts = np.linspace(0, 200, 999)
    rates = np.array([d.rate_at(t) for t in ts])
    assert rates.min() >= 1.0 - 1e-9 and rates.max() <= 5.0 + 1e-9
    scaled = d.scaled(2.0)
    assert scaled.base_rate == 2.0 and scaled.peak_rate == 10.0


def test_mmpp_phase_trace():
    mm = MarkovModulatedArrivals(rates=(0.5, 10.0), dwell_s=(10.0, 5.0))
    times, phases = mm.sample_with_phases(np.random.default_rng(1), 500)
    assert (np.diff(times) >= 0).all()
    assert set(np.unique(phases)) <= {0, 1}
    assert len(set(np.unique(phases))) == 2   # both phases visited


def test_registries_and_errors():
    assert set(ARRIVALS) == {"poisson", "diurnal", "mmpp"}
    assert set(DEMANDS) == {"zipf", "uniform"}
    assert set(LENGTHS) == {"pareto"}
    assert isinstance(make_arrival("poisson"), PoissonArrivals)
    with pytest.raises(KeyError):
        make_arrival("weibull")
    with pytest.raises(KeyError):
        scenario_workload("nope", 5)
    with pytest.raises(ValueError):
        PoissonArrivals(rate=0.0).sample(np.random.default_rng(0), 3)
    with pytest.raises(ValueError):
        DiurnalArrivals(base_rate=5.0, peak_rate=1.0).sample(
            np.random.default_rng(0), 3)


def test_scenario_presets():
    for name in TAIL_SCENARIOS:
        a = scenario_workload(name, 25, seed=2)
        b = scenario_workload(name, 25, seed=2)
        assert workload_fingerprint(a) == workload_fingerprint(b)
        # rate_scale compresses/stretches the arrival span only
        fast = scenario_workload(name, 25, seed=2, rate_scale=4.0)
        assert fast[-1].arrival_s < a[-1].arrival_s
        assert [s.true_len for j in fast for s in j.stages] \
            == [s.true_len for j in a for s in j.stages]
