"""Scheduler components: rho margin adaptation, SRTF ordering/aging/
preemption hysteresis, fitness routing feasibility. The robust-normalizer
bounds property lives in test_properties.py (skipped without hypothesis)."""
import numpy as np
import pytest

from repro.core.sched.fitness import (FitnessRouter, NodeSignal,
                                      StageRequest)
from repro.core.sched.margins import RhoEstimator
from repro.core.sched.srtf import QueuedStage, SRTFQueue, WorkflowProfileStore


def test_rho_tracks_underestimation_quantile():
    rho = RhoEstimator(quantile=0.9, ewma=1.0)
    rng = np.random.default_rng(0)
    for _ in range(600):
        pred = 100.0
        actual = pred * rng.uniform(0.8, 1.25)   # up to 25% under
        rho.observe(actual, pred)
    assert 0.1 <= rho.rho <= 0.3     # "in practice it falls in [0.1, 0.3]"
    assert rho.r_need(100.0) == pytest.approx(100 * (1 + rho.rho))


def test_rho_never_negative_or_huge():
    rho = RhoEstimator()
    for _ in range(50):
        rho.observe(50.0, 100.0)     # consistent OVERestimation
    assert rho.rho >= rho.lo


def test_srtf_orders_by_remaining_time():
    q = SRTFQueue()
    a = QueuedStage(1, 1, interactive=False, t_exec=5.0, t_future=20.0)
    b = QueuedStage(2, 2, interactive=False, t_exec=1.0, t_future=2.0)
    c = QueuedStage(3, 3, interactive=True, t_exec=50.0, t_future=50.0)
    for s in (a, b, c):
        q.push(s, now=0.0)
    # interactive strictly first, then shortest remaining
    assert q.pop(0.0) is c
    assert q.pop(0.0) is b
    assert q.pop(0.0) is a


def test_srtf_aging_promotes_waiters():
    q = SRTFQueue(aging_factor=1.0)
    old = QueuedStage(1, 1, interactive=False, t_exec=100.0, t_future=0.0,
                      enqueue_time=0.0)
    q.push(old, now=0.0)
    new = QueuedStage(2, 2, interactive=False, t_exec=10.0, t_future=0.0,
                      enqueue_time=200.0)
    q.push(new, now=200.0)
    q.refresh(200.0)   # old has aged 200s -> priority -100 beats 10
    assert q.pop(200.0) is old


def test_preemption_hysteresis_and_cooldown():
    q = SRTFQueue(preempt_gain_s=1.0, cooldown_s=100.0)
    run = QueuedStage(1, 1, interactive=False, t_exec=5.0, t_future=0.0)
    cand = QueuedStage(2, 2, interactive=True, t_exec=0.5, t_future=0.0)
    # below-threshold gain: no preemption
    assert not q.should_preempt(run, cand, running_remaining_s=0.5, now=0.0)
    # sufficient gain: preempt once...
    assert q.should_preempt(run, cand, running_remaining_s=50.0, now=1.0)
    # ...but cooldown blocks an immediate second preemption of the same job
    assert not q.should_preempt(run, cand, running_remaining_s=50.0, now=2.0)
    # and interactive work is never preempted for batch
    i_run = QueuedStage(3, 3, interactive=True, t_exec=5.0, t_future=0.0)
    b_cand = QueuedStage(4, 4, interactive=False, t_exec=0.1, t_future=0.0)
    assert not q.should_preempt(i_run, b_cand, 1e9, now=500.0)


def test_workflow_profile_median_and_backoff():
    store = WorkflowProfileStore(default_future=7.0)
    key = (1, 2, 3, 1)
    assert store.future_median(key) == 7.0          # cold default
    for v in (1.0, 9.0, 5.0):
        store.record(key, v)
    assert store.future_median(key) == 5.0
    # intent-bucket backoff
    store2 = WorkflowProfileStore(default_future=7.0)
    store2.record((1, 2, 3, 0), 4.0)
    assert store2.future_median((1, 2, 3, 2)) == 4.0


def _sig(node_id, cluster, headroom, qd=0.0, warm=()):
    return NodeSignal(node_id=node_id, cluster_id=cluster, headroom=headroom,
                      queue_delay_s=qd, warm_models=dict.fromkeys(warm, 0.0))


def test_fitness_filters_infeasible_and_prefers_warm():
    rtt = np.zeros((2, 2))
    router = FitnessRouter(rtt)
    req = StageRequest(stage_id=1, model="m", r_need=10e9,
                       interactive=False, src_cluster=0, t_exec=1.0)
    nodes = [_sig(0, 0, headroom=5e9),            # infeasible
             _sig(1, 0, headroom=12e9, warm=("m",)),
             _sig(2, 1, headroom=30e9)]
    t_act = lambda sig, m: 0.0 if m in sig.warm_models else 20.0
    c_deg = lambda sig, rq: None                   # no degradation plans
    sel = router.select(req, nodes, t_act, c_deg)
    assert sel is not None
    assert sel[0].node_id == 1    # warm + best-fit headroom wins


def test_fitness_interactive_prefers_near_cluster():
    rtt = np.array([[0.001, 0.2], [0.2, 0.001]])
    router = FitnessRouter(rtt, gamma=0.25)
    # seed the normalizer with both RTT scales
    for v in (0.001, 0.2):
        router.normalizer.observe("rtt", v)
    req = StageRequest(stage_id=1, model="m", r_need=1e9,
                       interactive=True, src_cluster=0, t_exec=1.0)
    nodes = [_sig(0, 0, headroom=2e9), _sig(1, 1, headroom=2e9)]
    sel = router.select(req, nodes, lambda s, m: 0.0, lambda s, r: 0.0)
    assert sel[0].node_id == 0
