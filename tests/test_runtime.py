"""Node runtime invariants: residency latency ordering, pinning, KV pool
overcommit. Property-based companions (capacity/accounting/pool/degradation
invariants under random operation sequences) live in test_properties.py,
which skips itself when hypothesis is unavailable."""
import numpy as np
import pytest

from repro.core.predictor.cost_model import synthetic_profile
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool
from repro.core.runtime.residency import (HierarchicalResidency, ModelState,
                                          RETRACE_COST_S)

PROFILES = {f"m{i}": synthetic_profile(f"m{i}", params_b=0.5 + i)
            for i in range(6)}


def test_residency_activation_latency_ordering():
    res = HierarchicalResidency(PROFILES, c_gpu=8e9, c_cpu=20e9, c_disk=60e9)
    m = "m2"
    t_remote = res.activation_latency(m)
    res.ensure_gpu(m)
    assert res.activation_latency(m) == 0.0
    res.sleep(m)
    t_sleep = res.activation_latency(m)
    res.demote_context(m)
    t_cpu = res.activation_latency(m)
    assert 0 < t_sleep < t_cpu < t_remote
    assert t_cpu - t_sleep == pytest.approx(RETRACE_COST_S)


def test_residency_pinned_never_evicted():
    res = HierarchicalResidency(PROFILES, c_gpu=8e9, c_cpu=30e9, c_disk=60e9)
    res.ensure_gpu("m3")
    res.pinned = {"m3"}
    for other in ("m0", "m1", "m2", "m4"):
        res.ensure_gpu(other)
        assert res.state["m3"] is ModelState.RUNNING


def test_kv_pool_overcommit_ratio():
    acc = MemoryAccountant(m_total=40e9)
    acc.register_weights("w", 10e9)
    acc.register_context("w", 1e9)
    pool = VirtualKVPool(acc, page_bytes=2 << 20, page_tokens=16)
    pool.set_virtual_budget("a", 60e9)
    pool.set_virtual_budget("b", 60e9)
    assert pool.overcommit_ratio() > 3.0   # Table V regime
