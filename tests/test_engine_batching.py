"""Iteration-level continuous batching with chunked prefill.

The parity contract is OUTPUT-LEVEL: per-request greedy token sequences from
the chunked engine must equal the monolithic path exactly (prefix cache on
and off, every zoo model with self-attention KV). Logits are allowed to
drift at ulp level — fixed-shape padded reductions reassociate differently
than per-length monolithic prefill — which greedy argmax absorbs.

Also pins the satellite contracts of the same PR: deque-backed waiting
queue with preserved requeue semantics, ``EngineStalledError`` from an
exhausted drain, ``step()`` returning only newly-finished requests, and the
compile-count telemetry staying flat across distinct prompt lengths.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime.accounting import MemoryAccountant
from repro.models import build_model
from repro.serving.engine import Engine, EngineStalledError, Request

CHUNK_ZOO = ("qwen3-8b", "starcoder2-15b")     # self-attention KV models


@pytest.fixture(scope="module", params=CHUNK_ZOO)
def zoo_model(request):
    cfg = get_config(request.param).reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(0, cfg.vocab, p))
            for p in (3, 7, 12, 5, 9, 14)]


def _drain_all(m, params, prompts, *, chunk, prefix_cache=False,
               sequential=False, max_new=6, max_slots=3, **kw):
    eng = Engine(m, params, MemoryAccountant(m_total=512e6),
                 max_slots=max_slots, s_max=64, kv_backend="ref",
                 prefix_cache=prefix_cache, prefill_chunk_tokens=chunk, **kw)
    out = {}
    if sequential:        # drain between prompts so later ones hit the index
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new))
            for r in eng.drain():
                out[r.req_id] = r
    else:
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new))
        for r in eng.drain():
            out[r.req_id] = r
    return eng, out


# ------------------------------------------------------- output-level parity
def test_chunked_matches_monolithic_every_zoo_model(zoo_model):
    cfg, m, params = zoo_model
    assert m.supports_chunked_prefill
    prompts = _prompts(cfg)
    _, mono = _drain_all(m, params, prompts, chunk=0)
    for chunk in (4, 8, 16):
        _, chk = _drain_all(m, params, prompts, chunk=chunk)
        assert {k: r.out for k, r in chk.items()} == \
               {k: r.out for k, r in mono.items()}, f"chunk={chunk}"


def test_chunked_matches_monolithic_with_prefix_cache(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(5)
    base = list(rng.integers(0, cfg.vocab, 40))
    prompts = [base,                          # indexes 2 full pages
               base[:32] + [3, 1, 4, 1, 5],   # hits both full pages
               base[:16] + [9] * 20,          # hits page 0 only
               base[:20] + [7] * 11]          # partial-page COW hit
    _, mono = _drain_all(m, params, prompts, chunk=0, sequential=True,
                         max_slots=2)
    for pc in (False, True):
        for chunk in (4, 16):
            eng, chk = _drain_all(m, params, prompts, chunk=chunk,
                                  prefix_cache=pc, sequential=True,
                                  max_slots=2)
            assert {k: r.out for k, r in chk.items()} == \
                   {k: r.out for k, r in mono.items()}, (pc, chunk)
            if pc:   # suffix chunks resumed AFTER the cached prefix pages
                assert [chk[k].prefill_avoided for k in sorted(chk)] == \
                       [0, 32, 16, 20]
            assert eng.arena.check_mirror()


def test_ssm_model_falls_back_to_monolithic():
    """A model without self-attention KV cannot chunk — the knob degrades
    to monolithic prefill instead of failing."""
    cfg = get_config("mamba2-2.7b").reduced()
    m = build_model(cfg)
    assert not m.supports_chunked_prefill
    params = m.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg)[:2]
    _, mono = _drain_all(m, params, prompts, chunk=0, max_new=4)
    eng, chk = _drain_all(m, params, prompts, chunk=8, max_new=4)
    assert eng.chunk_tokens == 0
    assert {k: r.out for k, r in chk.items()} == \
           {k: r.out for k, r in mono.items()}


# ----------------------------------------------- compile counter / telemetry
def test_prefill_compile_count_flat_across_prompt_lengths(tiny):
    cfg, m, params = tiny
    prompts = _prompts(cfg)
    assert len({len(p) for p in prompts}) == 6     # six distinct lengths
    mono_eng, _ = _drain_all(m, params, prompts, chunk=0)
    chk_eng, _ = _drain_all(m, params, prompts, chunk=4)
    assert mono_eng.prefill_compiles == 6          # one trace per length
    assert chk_eng.prefill_compiles == 1           # one fixed chunk shape
    # counters flow through the node snapshot for gateway aggregation
    total = sum(len(p) for p in prompts)
    assert chk_eng.stat_prefill_tokens == total
    assert chk_eng.stat_decode_tokens > 0
    assert chk_eng.stat_fused_steps > 0            # prefill+decode co-ran


def test_engine_counters_exposed_in_node_kv_stats(tiny):
    from repro.serving.node_runtime import NodeRuntime
    cfg, m, params = tiny
    host = jax.tree.map(np.asarray, params)
    node = NodeRuntime(0, 0, {cfg.name: m}, {cfg.name: host},
                       hbm_budget=1.2e9, max_slots=2, s_max=64,
                       prefill_chunk_tokens=4)
    node.submit(cfg.name, Request(req_id=0, tokens=[1, 2, 3, 4, 5],
                                  max_new=4))
    for _ in range(30):
        node.step()
        if not node.has_work():
            break
    st = node.kv_stats()
    assert st["engine_prefill_tokens"] == 5
    assert st["engine_decode_tokens"] > 0
    assert st["engine_prefill_compiles"] == 1
    assert st["engine_steps"] > 0


def test_ttft_stamped_on_finished_requests(tiny):
    cfg, m, params = tiny
    _, done = _drain_all(m, params, _prompts(cfg)[:3], chunk=4)
    assert all(r.ttft_s > 0 for r in done.values())
    _, done = _drain_all(m, params, _prompts(cfg)[:3], chunk=0)
    assert all(r.ttft_s > 0 for r in done.values())


# --------------------------------------------------------- token budget
def test_max_batch_tokens_defers_chunks_but_never_starves(tiny):
    cfg, m, params = tiny
    prompts = _prompts(cfg)
    _, mono = _drain_all(m, params, prompts, chunk=0)
    # budget of 8 tokens with chunk=8: at most one chunk advances per
    # iteration once decode slots are occupied, yet everything completes
    eng, chk = _drain_all(m, params, prompts, chunk=8, max_batch_tokens=8)
    assert {k: r.out for k, r in chk.items()} == \
           {k: r.out for k, r in mono.items()}
    assert eng.arena.mapped_pages() == 0


# ------------------------------------------------------------- satellites
def test_waiting_is_deque_and_requeue_preserves_order(tiny):
    """release_kv() must requeue evicted actives AHEAD of already-waiting
    requests in their original order (the old ``waiting[:0] = evicted``
    list semantics, now via deque.extendleft)."""
    from collections import deque
    cfg, m, params = tiny
    eng = Engine(m, params, MemoryAccountant(m_total=512e6), max_slots=2,
                 s_max=64, kv_backend="ref")
    assert isinstance(eng.waiting, deque)
    for i in range(4):
        eng.submit(Request(req_id=i, tokens=[1, 2, 3], max_new=8))
    eng.step()                        # admits 0 and 1; 2 and 3 wait
    assert set(eng.active) == {0, 1}
    eng.release_kv()                  # boundary-evict both actives
    assert [r.req_id for r in eng.waiting] == [0, 1, 2, 3]
    # cancel from the middle of the deque still works
    assert eng.cancel(2).req_id == 2
    assert [r.req_id for r in eng.waiting] == [0, 1, 3]


def test_drain_raises_typed_stall_error(tiny):
    cfg, m, params = tiny
    eng = Engine(m, params, MemoryAccountant(m_total=512e6), max_slots=2,
                 s_max=64, kv_backend="ref")
    eng.submit(Request(req_id=0, tokens=[1, 2, 3], max_new=50))
    with pytest.raises(EngineStalledError):
        eng.drain(max_steps=3)        # 50 tokens cannot finish in 3 steps
    # the engine is still consistent: a real drain completes afterwards
    done = eng.drain()
    assert len(done) == 1 and len(done[0].out) == 50


def test_step_returns_only_newly_finished(tiny):
    cfg, m, params = tiny
    eng = Engine(m, params, MemoryAccountant(m_total=512e6), max_slots=2,
                 s_max=64, kv_backend="ref")
    eng.submit(Request(req_id=0, tokens=[1, 2, 3], max_new=2))
    eng.submit(Request(req_id=1, tokens=[4, 5, 6], max_new=6))
    first = eng.step()                # req 0 finishes (prefill + 1 decode)
    assert [r.req_id for r in first] == [0]
    mid = eng.step()                  # req 1 still decoding
    assert mid == []
    while eng.active or eng.waiting:
        last = eng.step()
    assert [r.req_id for r in last] == [1]
    # the accumulated history stays on .finished for wholesale drainers
    assert [r.req_id for r in eng.finished] == [0, 1]


def test_evict_mid_chunked_prefill_frees_partial_pages(tiny):
    cfg, m, params = tiny
    rng = np.random.default_rng(3)
    acc = MemoryAccountant(m_total=512e6)
    eng = Engine(m, params, acc, max_slots=2, s_max=64, kv_backend="ref",
                 prefill_chunk_tokens=4)
    eng.submit(Request(req_id=0, tokens=list(rng.integers(0, cfg.vocab, 40)),
                       max_new=6))
    eng.step()                        # first chunk written, prefill ongoing
    assert eng._prefill_pos.get(0) == 4
    assert eng.arena.mapped_pages() > 0
    req = eng.evict(0)
    assert req is not None and req.out == []
    assert eng._prefill_pos == {}     # streaming cursor dropped
    assert eng.arena.mapped_pages() == 0 and eng.arena.mapped_rows() == 0
    assert acc.m_kv == pytest.approx(0.0)
    assert eng.arena.check_mirror()
