"""Fault recovery e2e: SIGKILL one socket worker mid-run under the wall
clock and prove the membership plane absorbs it — the dead node's in-flight
stages re-enter the ready queue, the run completes on the survivor, the
death is typed telemetry, and nothing hangs or double-completes."""
import os
import signal

import numpy as np

from repro.data.tracegen import generate_trace
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])


def test_kill_worker_mid_run_requeues_and_completes():
    spec = ClusterSpec(nodes=(NodeSpec(0), NodeSpec(1)),
                       model_names=("qwen3-8b",))
    jobs = jobs_from_trace(generate_trace(n_jobs=6, seed=3, rate=4.0),
                           n_clusters=2, gen_cap=12)
    fleet = build_fleet(spec, backend="socket")
    gw = ClusterGateway(fleet, RTT, policy="fcfs",
                        cfg=GatewayConfig(node_backend="socket",
                                          clock="wall", heartbeat_s=0.05))
    victim = fleet[0]
    try:
        gw.warmup()
        gw.submit_jobs(jobs)
        gw.clock.restart()
        gw.clock.set_deadline(180.0)
        killed = False
        while gw._unfinished() and not gw.clock.expired():
            gw.step()
            if not killed and any(r.submitted and r.node_id == victim.node_id
                                  for r in gw.inflight.values()):
                os.kill(victim.proc.pid, signal.SIGKILL)
                killed = True
        assert killed, "victim node never received submitted work"
        m = gw.metrics()
        total = sum(len(j.stages) for j in jobs)

        # the run survived the death and finished everything, exactly once
        assert m.run_outcome == "completed"
        assert m.finished_jobs == len(jobs)
        assert m.finished_stages == total
        fins = [e for e in gw.telemetry.events.values() if e.finish_t > 0]
        assert len(fins) == total

        # the death is first-class telemetry
        assert m.node_deaths == 1
        assert m.requeued_stages >= 1
        (death,) = m.death_events
        assert death.node_id == victim.node_id
        assert len(death.requeued_stages) == m.requeued_stages
        assert m.liveness[victim.node_id] == "dead"
        assert all(v == "healthy"
                   for n, v in m.liveness.items() if n != victim.node_id)

        # every evacuated stage finished on a surviving node
        for sid in death.requeued_stages:
            ev = gw.telemetry.events[sid]
            assert ev.finish_t > 0 and ev.node_id != victim.node_id
            assert ev.worker_deaths >= 1
    finally:
        gw.close()
        gw.close()                       # close is idempotent post-death
