"""Fault recovery e2e: SIGKILL one socket worker mid-run under the wall
clock and prove the membership plane absorbs it — the dead node's in-flight
stages re-enter the ready queue, the run completes on the survivor, the
death is typed telemetry, and nothing hangs or double-completes. The
FaultPlan tests drive the same recovery machinery from a scripted schedule
(clock-plane events) instead of a hand-rolled step loop."""
import os
import signal

import numpy as np

from repro.data.tracegen import generate_trace
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   jobs_from_trace)
from repro.serving.faultplan import (DegradeLink, FaultPlan, KillWorker,
                                     RestoreLink)
from repro.serving.gateway import ClusterGateway, GatewayConfig

RTT = np.array([[0.001, 0.04], [0.04, 0.001]])


def test_kill_worker_mid_run_requeues_and_completes():
    spec = ClusterSpec(nodes=(NodeSpec(0), NodeSpec(1)),
                       model_names=("qwen3-8b",))
    jobs = jobs_from_trace(generate_trace(n_jobs=6, seed=3, rate=4.0),
                           n_clusters=2, gen_cap=12)
    fleet = build_fleet(spec, backend="socket")
    gw = ClusterGateway(fleet, RTT, policy="fcfs",
                        cfg=GatewayConfig(node_backend="socket",
                                          clock="wall", heartbeat_s=0.05))
    victim = fleet[0]
    try:
        gw.warmup()
        gw.submit_jobs(jobs)
        gw.clock.restart()
        gw.clock.set_deadline(180.0)
        killed = False
        while gw._unfinished() and not gw.clock.expired():
            gw.step()
            if not killed and any(r.submitted and r.node_id == victim.node_id
                                  for r in gw.inflight.values()):
                os.kill(victim.proc.pid, signal.SIGKILL)
                killed = True
        assert killed, "victim node never received submitted work"
        m = gw.metrics()
        total = sum(len(j.stages) for j in jobs)

        # the run survived the death and finished everything, exactly once
        assert m.run_outcome == "completed"
        assert m.finished_jobs == len(jobs)
        assert m.finished_stages == total
        fins = [e for e in gw.telemetry.events.values() if e.finish_t > 0]
        assert len(fins) == total

        # the death is first-class telemetry
        assert m.node_deaths == 1
        assert m.requeued_stages >= 1
        (death,) = m.death_events
        assert death.node_id == victim.node_id
        assert len(death.requeued_stages) == m.requeued_stages
        assert m.liveness[victim.node_id] == "dead"
        assert all(v == "healthy"
                   for n, v in m.liveness.items() if n != victim.node_id)

        # every evacuated stage finished on a surviving node
        for sid in death.requeued_stages:
            ev = gw.telemetry.events[sid]
            assert ev.finish_t > 0 and ev.node_id != victim.node_id
            assert ev.worker_deaths >= 1
    finally:
        gw.close()
        gw.close()                       # close is idempotent post-death


def test_faultplan_scripted_kill_and_link_degradation_socket():
    """Scripted plan on the socket backend under the wall clock: the victim
    worker is SIGKILLed at a scheduled time while a cross-cluster link is
    degraded — the run must complete on the survivor with every stage
    finished exactly once, typed death telemetry, and a bounded recovery
    time."""
    deadline_s = 180.0
    spec = ClusterSpec(nodes=(NodeSpec(0), NodeSpec(1)),
                       model_names=("qwen3-8b",))
    jobs = jobs_from_trace(generate_trace(n_jobs=8, seed=3, rate=6.0),
                           n_clusters=2, gen_cap=12)
    fleet = build_fleet(spec, backend="socket")
    gw = ClusterGateway(fleet, RTT, policy="fcfs",
                        cfg=GatewayConfig(node_backend="socket",
                                          clock="wall", heartbeat_s=0.05,
                                          max_run_s=deadline_s))
    victim = fleet[0].node_id
    # anchor the schedule to the trace's arrival span: the run cannot drain
    # before the last arrival, so every event is guaranteed to fire
    span = max(j.arrival_s for j in jobs)
    plan = FaultPlan([
        KillWorker(at_s=0.6 * span, node_id=victim),
        DegradeLink(at_s=0.2 * span, src_cluster=0, dst_cluster=1,
                    factor=20.0),
        RestoreLink(at_s=span, src_cluster=0, dst_cluster=1),
    ])
    try:
        gw.warmup()
        m = gw.run(jobs, fault_plan=plan)
        total = sum(len(j.stages) for j in jobs)

        # every scripted event fired, in schedule order
        assert [w.split(":")[0] for _, w in plan.fired] == \
            ["degrade link 0<->1 x20", f"kill node {victim}",
             "restore link 0<->1"]
        # the degraded link really was restored before the run ended
        assert np.allclose(gw.rtt_s, RTT)

        # exactly-once completion on the survivors
        assert m.run_outcome == "completed"
        assert m.finished_jobs == len(jobs)
        assert m.finished_stages == total
        fins = [e for e in gw.telemetry.events.values() if e.finish_t > 0]
        assert len(fins) == total

        # typed death + bounded recovery: everything the death evacuated
        # was re-served (on a survivor) well inside the run deadline
        assert m.node_deaths == 1
        (death,) = m.death_events
        assert death.node_id == victim
        assert m.liveness[victim] == "dead"
        for sid in death.requeued_stages:
            ev = gw.telemetry.events[sid]
            assert ev.finish_t > 0 and ev.node_id != victim
        if death.requeued_stages:
            assert 0.0 < m.recovery_time_s < deadline_s
    finally:
        gw.close()


def test_faultplan_virtual_inproc_deterministic():
    """The same plan on the in-process fleet under the virtual clock is
    fully deterministic: two runs produce identical completion sets and
    identical injected-fault times."""
    spec = ClusterSpec(nodes=(NodeSpec(0), NodeSpec(1)),
                       model_names=("qwen3-8b",))
    trace = generate_trace(n_jobs=6, seed=3, rate=4.0)

    def one_run():
        fleet = build_fleet(spec)
        jobs = jobs_from_trace(trace, n_clusters=2, gen_cap=8)
        plan = FaultPlan([
            KillWorker(at_s=0.6, node_id=0),
            DegradeLink(at_s=0.7, src_cluster=0, dst_cluster=1,
                        factor=30.0),
        ])
        gw = ClusterGateway(fleet, RTT.copy(), policy="fcfs")
        m = gw.run(jobs, fault_plan=plan)
        events = {sid: (e.node_id, e.out_len, e.finish_t)
                  for sid, e in gw.telemetry.events.items()
                  if e.finish_t > 0}
        gw.close()
        return m, events, plan.fired

    m1, ev1, fired1 = one_run()
    m2, ev2, fired2 = one_run()
    total = sum(len(j.stages) for j in trace)
    assert ev1 == ev2 and fired1 == fired2
    assert m1.node_deaths == 1 and m1.finished_stages == total
    assert len(ev1) == total
    assert m1.makespan_s == m2.makespan_s
    assert m1.recovery_time_s == m2.recovery_time_s


def test_faultplan_single_use():
    plan = FaultPlan([KillWorker(at_s=1.0, node_id=0)])

    class _Clock:
        def now(self):
            return 0.0

        def call_at(self, t, payload):
            pass

    class _GW:
        clock = _Clock()

    plan.arm(_GW())
    import pytest
    with pytest.raises(RuntimeError):
        plan.arm(_GW())
