"""Unified scheduling-policy API: registry completeness, substrate-
statelessness (one policy object reusable across repeated runs), and
sim/live decision parity — the same maestro instance drives the trace
simulator and the real-engine gateway over one mini-trace and must make the
same admission/routing decisions where the substrates are semantically
identical (forced-choice topology, contention-forced queue order)."""
import numpy as np
import pytest

from _stubs import StubPred
from repro.core.predictor.features import StageObservation
from repro.core.sched.policies import (POLICIES, FCFS, Maestro, make_policy,
                                       registered_policies)
from repro.data.tracegen import JobRecord, StageRecord, generate_trace
from repro.serving.cluster import (ClusterSpec, LiveJob, LiveStage, NodeSpec,
                                   build_fleet, build_zoo)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.sim.simulator import SimConfig, Simulator

EXPECTED = {"fcfs", "least-loaded", "edf", "oracle-srtf", "maestro",
            "maestro-np", "baseline-lb", "binpack", "maestro-aff"}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_covers_all_paper_policies():
    assert EXPECTED <= set(registered_policies())
    for name in EXPECTED:
        assert POLICIES[name].name == name


def test_make_policy_errors():
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("no-such-policy")
    for name in sorted(EXPECTED):
        if POLICIES[name].needs_predictor:
            with pytest.raises(ValueError, match="predictor"):
                make_policy(name)
        else:
            assert make_policy(name).name == name


# ---------------------------------------------------------------------------
# substrate-statelessness: reuse one instance across repeated runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mk", [lambda: FCFS(),
                                lambda: Maestro(StubPred(length=20.0))])
def test_policy_instance_reusable_across_runs(mk):
    """setup() resets all per-run state, so back-to-back runs with ONE
    policy object reproduce the fresh-object result exactly (no leaked
    queue/calibration state — the old GatewayPolicy.bind coupling)."""
    pol = mk()
    jobs = lambda: generate_trace(60, rate=2.0, seed=17)
    cfg = SimConfig(nodes_per_cluster=(1, 1))
    first = Simulator(jobs(), pol, cfg).run()
    again = Simulator(jobs(), pol, cfg).run()      # same object, reused
    fresh = Simulator(jobs(), mk(), cfg).run()
    assert first == again == fresh
    assert first.finished_jobs == 60


# ---------------------------------------------------------------------------
# sim/live parity
# ---------------------------------------------------------------------------

RTT = np.array([[0.001, 0.08], [0.08, 0.001]])
ZOO = ("qwen3-8b",)


def _obs(sid: int, prompt_len: int) -> StageObservation:
    return StageObservation(app=0, role=0, position=0.0, invocation_idx=sid,
                            tools_available=0, cot=False,
                            prompt_len=prompt_len, model_id=0,
                            text="parity stage", src_cluster=0)


# per-stage predicted length = prompt_len / 4 — distinct, deterministic, and
# identical for both substrates (the live decode budget of 16 caps none of
# the real stages, so relative order is preserved everywhere)
LENS = {0: 12, 1: 36, 2: 60}          # stage_id -> prompt_len (l_hat = /4)
GIANT_PROMPT = 4_000_000              # l_hat 1e6 -> R_need >> any node


def _record(policy, log):
    """Wrap policy.route (re-wrappable) to record (stage_id, decision)."""
    cls_route = type(policy).route

    def route(sub, stage, r_need):
        nid = cls_route(policy, sub, stage, r_need)
        log.append((stage.stage_id, nid))
        return nid

    policy.route = route
    return policy


def _sim_jobs():
    jobs = []
    for sid, plen in {**LENS, 3: GIANT_PROMPT}.items():
        st = StageRecord(job_id=sid, stage_id=sid, deps=[],
                         obs=_obs(sid, plen), interactive=True,
                         true_len=max(plen // 4, 1), tool_call=False)
        jobs.append(JobRecord(job_id=sid, app="parity", interactive=True,
                              arrival_s=0.0, stages=[st]))
    return jobs


def _live_jobs():
    jobs = []
    for sid, plen in {**LENS, 3: GIANT_PROMPT}.items():
        st = LiveStage(stage_id=sid, job_id=sid, deps=[],
                       obs=_obs(sid, plen), interactive=True,
                       tokens=[1, 2, 3, 4, 5, 6], max_new=16)
        jobs.append(LiveJob(job_id=sid, app="parity", interactive=True,
                            arrival_s=0.0, stages=[st]))
    return jobs


def test_sim_live_parity_maestro():
    """One maestro instance, both substrates, matched 2-cluster topology:
    node 0 (near) is the only feasible node, node 1 (remote) can never admit,
    and single-slot contention forces the SRTF order to be observable. The
    successful dispatch sequence, the routed node of every dispatch, and the
    admission rejection of the oversized job must agree across planes."""
    pred = StubPred(length=lambda obs: obs.prompt_len / 4)
    pol = Maestro(pred)

    # --- sim plane: 2 clusters x 1 node, node 1 starved of HBM
    sim_log = []
    sim = Simulator(_sim_jobs(), _record(pol, sim_log),
                    SimConfig(nodes_per_cluster=(1, 1), max_concurrency=1),
                    rtt=RTT)
    sim.nodes[1].acc.m_total = 1e9       # weights floor alone exceeds this
    r_sim = sim.run()

    # --- live plane: same topology on real engines (SAME policy object —
    # setup() must fully reset the sim run's controller state)
    zoo, host = build_zoo(ZOO, seed=1)
    fleet = build_fleet(ClusterSpec(
        nodes=(NodeSpec(0, max_slots=1, hbm_budget=1.2e9),
               NodeSpec(1, max_slots=1, hbm_budget=20e6)),
        rtt_s=RTT, model_names=ZOO), zoo=zoo, host=host)
    live_log = []
    gw = ClusterGateway(fleet, RTT, policy=_record(pol, live_log),
                        cfg=GatewayConfig(reject_limit=500))
    m_live = gw.run(_live_jobs())

    # the three feasible single-stage jobs finish on both planes; the giant
    # job is rejected by admission on both
    assert r_sim.finished_jobs == 3
    assert m_live.finished_jobs == 3
    assert m_live.dropped_jobs == 1
    assert m_live.admission_rejections > 0

    def dispatched(log):
        return [(sid, nid) for sid, nid in log if nid is not None]

    # identical dispatch order (workflow-aware SRTF: shortest predicted
    # remaining first) and identical routing (forced to the near node)
    assert dispatched(sim_log) == dispatched(live_log) == [(0, 0), (1, 0),
                                                           (2, 0)]
    # the oversized stage is refused by every routing attempt on both planes
    assert (3, None) in sim_log and (3, None) in live_log
    for log in (sim_log, live_log):
        assert all(nid is None for sid, nid in log if sid == 3)
