"""Serving engine + node runtime integration (real JAX execution, tiny models)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.runtime.accounting import MemoryAccountant
from repro.models import build_model
from repro.serving.engine import Engine, PromptTooLongError, Request
from repro.serving.node_runtime import NodeRuntime


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_engine_continuous_batching(tiny_model):
    cfg, m, params = tiny_model
    acc = MemoryAccountant(m_total=256e6)
    eng = Engine(m, params, acc, max_slots=3, s_max=64)
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i, tokens=list(rng.integers(0, cfg.vocab, 8)),
                    max_new=10) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    done = eng.drain()
    assert len(done) == 8
    for r in done:
        assert len(r.out) >= 10
    assert acc.check_invariant()
    assert acc.m_kv == pytest.approx(0.0)     # everything reclaimed
    assert not eng.active and not eng.waiting


def test_engine_matches_unbatched_decode(tiny_model):
    """Greedy continuous-batched output == one-at-a-time decoding."""
    cfg, m, params = tiny_model
    prompt = list(range(1, 9))
    acc = MemoryAccountant(m_total=256e6)
    eng = Engine(m, params, acc, max_slots=2, s_max=64)
    eng.submit(Request(req_id=0, tokens=prompt, max_new=6))
    eng.submit(Request(req_id=1, tokens=prompt[::-1], max_new=6))
    done = {r.req_id: r.out for r in eng.drain()}

    acc2 = MemoryAccountant(m_total=256e6)
    for rid, toks in ((0, prompt), (1, prompt[::-1])):
        solo = Engine(m, params, acc2, max_slots=1, s_max=64)
        solo.submit(Request(req_id=99, tokens=list(toks), max_new=6))
        out = solo.drain()[0].out
        assert out == done[rid], (rid, out, done[rid])


def test_engine_backpressure(tiny_model):
    """With a tiny memory budget, admission rejects instead of OOMing."""
    cfg, m, params = tiny_model
    alpha = m.cfg.kv_bytes_per_token()
    acc = MemoryAccountant(m_total=alpha * 120.0)   # ~2 sequences worth
    eng = Engine(m, params, acc, max_slots=4, s_max=48)
    for i in range(6):
        eng.submit(Request(req_id=i, tokens=[1, 2, 3, 4], max_new=8))
    done = eng.drain()
    assert len(done) == 6           # eventually everyone runs
    assert acc.check_invariant()


def test_prompt_longer_than_window_rejected_typed(tiny_model):
    """Prompts that cannot fit s_max raise at submit() instead of silently
    overflowing the prefill write."""
    cfg, m, params = tiny_model
    eng = Engine(m, params, MemoryAccountant(m_total=256e6), max_slots=2,
                 s_max=16)
    with pytest.raises(PromptTooLongError):
        eng.submit(Request(req_id=0, tokens=list(range(16)), max_new=4))
    eng.submit(Request(req_id=1, tokens=list(range(15)), max_new=4))
    assert len(eng.drain()) == 1                 # boundary prompt still runs


def test_release_observes_the_admitted_reservation(tiny_model):
    """rho.observe must be fed the R_need admission charged, not a value
    recomputed after earlier releases already moved the shared estimator."""
    cfg, m, params = tiny_model
    eng = Engine(m, params, MemoryAccountant(m_total=256e6), max_slots=1,
                 s_max=64)
    needs, observed = [], []
    orig_need, orig_obs = eng.rho.r_need, eng.rho.observe
    eng.rho.r_need = lambda x: needs.append(orig_need(x)) or needs[-1]
    eng.rho.observe = \
        lambda a, r: observed.append(r) or orig_obs(a, r)
    rng = np.random.default_rng(1)
    for i in range(10):      # pred_len << actual so rho moves mid-stream
        eng.submit(Request(req_id=i, tokens=list(rng.integers(0, 64, 6)),
                           max_new=8, pred_len=1.0))
    eng.drain()
    assert len(needs) == 10                      # r_need at admission ONLY
    assert eng.rho.rho > eng.rho.lo              # estimator really moved
    for got, want in zip(observed, needs):
        assert got == pytest.approx(want)


def test_sleep_frees_engine_kv_and_recovers_headroom():
    """Regression for the sleep leak: offloading a model must free its arena
    pages AND its dense state cache, and the accountant must reflect it."""
    zoo, host = {}, {}
    for name in ("qwen3-8b", "mamba2-2.7b"):
        c = get_config(name).reduced()
        mm = build_model(c)
        zoo[name] = mm
        host[name] = jax.tree.map(np.asarray, mm.init(jax.random.PRNGKey(2)))
    node = NodeRuntime(0, 0, zoo, host, hbm_budget=1e9, max_slots=2, s_max=48)
    node.activate("mamba2-2.7b")
    node.submit("mamba2-2.7b", Request(req_id=0, tokens=[3, 4, 5], max_new=4))
    node.step()                                  # admitted + decoding
    eng = node.engines["mamba2-2.7b"]
    assert eng._state_bytes > 0                  # SSM state is accounted
    assert eng.pool.n_pages > 0
    h_active = node.acc.headroom
    node.sleep("mamba2-2.7b")
    recovered = node.acc.headroom - h_active
    weights = node.profiles["mamba2-2.7b"].weight_bytes
    assert recovered >= weights                  # weights AND KV came back
    assert eng._state_bytes == 0 and eng.cache is None
    assert node.arena.mapped_pages() == 0
    assert eng.waiting                           # in-flight work requeued
    # self-heal: step() reactivates and the requeued request completes
    out = {}
    for _ in range(30):
        for mdl, reqs in node.step().items():
            out.setdefault(mdl, []).extend(reqs)
    assert len(out.get("mamba2-2.7b", [])) == 1
    assert len(out["mamba2-2.7b"][0].out) >= 4


def test_node_runtime_colocation_and_warm_reactivation():
    zoo, host = {}, {}
    for name in ("qwen3-8b", "starcoder2-15b"):
        c = get_config(name).reduced()
        mm = build_model(c)
        zoo[name] = mm
        host[name] = jax.tree.map(np.asarray, mm.init(jax.random.PRNGKey(1)))
    node = NodeRuntime(0, 0, zoo, host, hbm_budget=1e9, max_slots=2, s_max=48)
    t_cold = node.activate("qwen3-8b")
    node.submit("qwen3-8b", Request(req_id=0, tokens=[5, 6, 7], max_new=4))
    for _ in range(8):
        node.step()
    node.sleep("qwen3-8b")
    assert "qwen3-8b" not in node.device_params
    t_warm = node.activate("qwen3-8b")
    assert t_warm < t_cold            # executable cache survived (Fig. 10)
    sig = node.signal()
    assert sig.headroom > 0
    assert "qwen3-8b" in sig.warm_models
