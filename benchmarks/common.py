"""Shared benchmark plumbing: trace + predictor caching, result output."""
from __future__ import annotations

import json
import pickle
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"
CACHE = RESULTS / ".cache"


def save_result(name: str, payload) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1))


def banner(title: str) -> None:
    print(f"\n=== {title} " + "=" * max(0, 68 - len(title)))


def get_trace(n_jobs: int, seed: int = 11, **kw):
    from repro.data.tracegen import generate_trace
    return generate_trace(n_jobs, seed=seed, **kw)


def get_predictor(n_jobs: int = 2500, fast: bool = False):
    """Train (or load cached) the Maestro predictor on a recorded trace."""
    CACHE.mkdir(parents=True, exist_ok=True)
    tag = f"pred_{n_jobs}_{'fast' if fast else 'full'}.pkl"
    f = CACHE / tag
    if f.exists():
        with open(f, "rb") as fh:
            return pickle.load(fh)
    from repro.core.predictor import MaestroPred, PredictorConfig
    from repro.core.predictor.gbdt import GBDTConfig
    from repro.data.tracegen import stratified_temporal_split
    jobs = get_trace(n_jobs)
    train, _ = stratified_temporal_split(jobs)
    if fast:
        cfg = PredictorConfig(
            cls=GBDTConfig(objective="logloss", n_trees=30, max_leaves=7),
            reg=GBDTConfig(n_trees=40, max_leaves=15))
    else:
        cfg = PredictorConfig(
            cls=GBDTConfig(objective="logloss", n_trees=80, max_leaves=31),
            reg=GBDTConfig(n_trees=120, max_leaves=31))
    t0 = time.time()
    mp = MaestroPred(cfg).fit(
        [s.obs for s in train],
        np.array([s.true_len for s in train], float),
        np.array([float(s.tool_call) for s in train]))
    print(f"[common] trained predictor on {len(train)} stages "
          f"({time.time()-t0:.0f}s)")
    with open(f, "wb") as fh:
        pickle.dump(mp, fh)
    return mp
