"""Engine iteration-scheduler benchmark: chunked prefill vs monolithic.

Serves the same varied-prompt-length trace through the live gateway on an
IDENTICAL fixed fleet twice — once with monolithic prefill
(``prefill_chunk_tokens=0``, the pre-PR engine loop) and once with the
token-budget iteration scheduler (fixed-width prefill chunks fused with
decode under ``max_batch_tokens``) — and reports the throughput and TTFT
deltas. Persisted by ``benchmarks.run`` as ``BENCH_engine_batching.json``.

Three legs:

* **parity** (virtual clock, deterministic): both engine configurations
  must finish the SAME stage set with the SAME per-stage output lengths —
  the gateway-level restatement of the engine's output-level parity
  contract (greedy tokens identical, chunked vs monolithic). Asserted on
  every run including CI smoke.
* **wall/monolithic vs wall/chunked**: real-elapsed-time serving after
  ``gw.warmup()``. Monolithic prefill re-traces once per distinct prompt
  length per engine (warmup can only cover one length), so on a trace with
  many prompt lengths its measured window is dominated by recompiles; the
  chunked engine runs every prompt through ONE compiled chunk shape. The
  headline columns are ``chunked_speedup_x`` (ratio of
  ``throughput_stages_per_s``, asserted ≥ 2x on sized runs) and the TTFT
  p95 reduction (asserted whenever both legs report one — chunking bounds
  time-to-first-schedule by the chunk width instead of the longest
  queued prompt, and skips the per-length retrace stall).

Wall rows are machine-dependent and never clobber virtual baselines; like
``BENCH_gateway_wall.json`` they are re-baselined per host (see
docs/BENCHMARKS.md).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from benchmarks.common import banner, get_trace
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet

#: prompt-length cap handed to jobs_from_trace — deliberately HIGH so the
#: trace carries many distinct prompt lengths (the regime where monolithic
#: prefill pays one retrace per length and chunking pays one total)
PROMPT_CAP = 16


#: the fleet serves the self-attention zoo models only: mamba2's SSM
#: prefill cannot chunk (the knob degrades to monolithic on BOTH legs —
#: covered by tests), so including it would only add identical per-length
#: retrace cost to both legs and dilute the measured effect
ZOO = ("qwen3-8b", "starcoder2-15b")


def _spec(chunk: int, budget: Optional[int]) -> ClusterSpec:
    # same fixed fleet for every leg (3 nodes over 3 clusters, batch-8
    # engines, roomy HBM) — ONLY the iteration-scheduler knobs differ
    mk = lambda c: NodeSpec(c, max_slots=8, hbm_budget=2e9,  # noqa: E731
                            prefill_chunk_tokens=chunk,
                            max_batch_tokens=budget)
    return ClusterSpec(nodes=(mk(0), mk(1), mk(2)), model_names=ZOO)


def _serve(chunk: int, budget: Optional[int], trace, *, clock: str,
           backend: str, seed: int, gen_cap: int, max_run_s: float,
           warmup: bool):
    spec = _spec(chunk, budget)
    fleet = build_fleet(spec, backend=backend)
    try:
        gw = ClusterGateway(
            fleet, spec.rtt_s, policy="fcfs",
            cfg=GatewayConfig(clock=clock, node_backend=backend,
                              max_inflight_per_node=12,
                              max_run_s=max_run_s))
        if warmup:
            gw.warmup()
        jobs = jobs_from_trace(trace, n_clusters=spec.n_clusters, seed=seed,
                               prompt_cap=PROMPT_CAP, gen_cap=gen_cap)
        m = gw.run(jobs)
        outs = {sid: e.out_len for sid, e in gw.telemetry.events.items()}
    finally:
        close_fleet(fleet)
    return m, outs


def main(n_jobs: int = 24, rate: float = 8.0, seed: int = 7,
         backend: str = "inproc", gen_cap: int = 16, chunk: int = 16,
         max_batch_tokens: int = 64, repeats: int = 2,
         max_run_s: float = 900.0, assert_speedup: bool = True) -> Dict:
    banner(f"engine-batching: chunked prefill vs monolithic ({n_jobs} jobs, "
           f"chunk={chunk}, budget={max_batch_tokens}, {backend} fleet)")
    trace = get_trace(n_jobs, seed=seed, rate=rate)
    legs = {"monolithic": (0, None), "chunked": (chunk, max_batch_tokens)}

    # ---- parity leg: deterministic virtual clock, outputs must match
    parity: Dict[str, Dict[int, int]] = {}
    for name, (c, b) in legs.items():
        m, outs = _serve(c, b, trace, clock="virtual", backend=backend,
                         seed=seed, gen_cap=gen_cap, max_run_s=max_run_s,
                         warmup=False)
        assert m.finished_jobs == n_jobs, \
            f"parity/{name}: {m.finished_jobs}/{n_jobs} finished " \
            f"({m.run_outcome})"
        parity[name] = outs
        if name == "chunked":
            assert m.engine_prefill_compiles > 0
    assert parity["chunked"] == parity["monolithic"], \
        "chunked engine diverged from monolithic outputs"
    print(f"[engine-batching] parity: {len(parity['chunked'])} stages, "
          f"chunked outputs == monolithic outputs")

    # ---- wall legs: interleaved repeats, best-of per leg
    rows: List[Dict] = []
    best: Dict[str, Dict[str, float]] = {
        n: {"tps": 0.0, "ttft": float("inf")} for n in legs}
    for rep in range(max(1, repeats)):
        for name, (c, b) in legs.items():
            t0 = time.time()
            m, _ = _serve(c, b, trace, clock="wall", backend=backend,
                          seed=seed, gen_cap=gen_cap, max_run_s=max_run_s,
                          warmup=True)
            wall = time.time() - t0
            # completion, not latency: wall rows may never flake CI
            assert m.finished_jobs > 0, \
                f"wall/{name}: no jobs finished ({m.run_outcome})"
            best[name]["tps"] = max(best[name]["tps"],
                                    m.throughput_stages_per_s)
            if m.ttft_p95_s > 0:
                best[name]["ttft"] = min(best[name]["ttft"], m.ttft_p95_s)
            row = m.row()
            row["leg"] = name
            row["repeat"] = rep
            row["prefill_chunk_tokens"] = c
            row["max_batch_tokens"] = b
            rows.append(row)
            print(f"[engine-batching] {name:>10} r{rep}: "
                  f"tput={m.throughput_stages_per_s:.2f} st/s "
                  f"ttft_p95={m.ttft_p95_s:.3f}s "
                  f"prefill_compiles={m.engine_prefill_compiles} "
                  f"fused_steps={m.engine_fused_steps} "
                  f"fin={m.finished_jobs}/{n_jobs} ({wall:.0f}s wall)")

    speedup = best["chunked"]["tps"] / max(best["monolithic"]["tps"], 1e-9)
    ttft_ratio = (best["monolithic"]["ttft"] / best["chunked"]["ttft"]
                  if best["chunked"]["ttft"] < float("inf")
                  and best["monolithic"]["ttft"] < float("inf") else 0.0)
    print(f"[engine-batching] chunked speedup {speedup:.2f}x "
          f"(tput {best['monolithic']['tps']:.2f} -> "
          f"{best['chunked']['tps']:.2f} st/s), "
          f"ttft p95 {best['monolithic']['ttft']:.3f}s -> "
          f"{best['chunked']['ttft']:.3f}s ({ttft_ratio:.1f}x better)")
    # TTFT bar: chunking removes the per-length retrace stall in front of
    # the first token, a >10x effect on CPU — asserted even on smoke
    if ttft_ratio:
        assert best["chunked"]["ttft"] < best["monolithic"]["ttft"], \
            f"chunked TTFT p95 did not improve: {best}"
    if assert_speedup:
        # the acceptance bar for the iteration scheduler (sized runs only)
        assert speedup >= 2.0, \
            f"chunked throughput speedup {speedup:.2f}x < 2x ({best})"

    return {
        "n_jobs": n_jobs,
        "n_stages": sum(len(j.stages) for j in trace),
        "rate_jobs_per_s": rate,
        "gen_cap": gen_cap,
        "prompt_cap": PROMPT_CAP,
        "prefill_chunk_tokens": chunk,
        "max_batch_tokens": max_batch_tokens,
        "nodes": 3,
        "max_slots": 8,
        "zoo": list(ZOO),
        "node_backend": backend,
        "repeats": repeats,
        "warmup": True,
        "parity_stages": len(parity["chunked"]),
        "chunked_speedup_x": round(speedup, 2),
        "ttft_p95_monolithic_s": round(best["monolithic"]["ttft"], 4),
        "ttft_p95_chunked_s": round(best["chunked"]["ttft"], 4),
        "ttft_improvement_x": round(ttft_ratio, 2),
        "rows": rows,
    }


if __name__ == "__main__":
    main()
