"""Table II: boundary preemption under extreme load (lambda=5.0, batch
ratio 0.6) as the node count grows — Maestro vs Maestro w/o preemption."""
from __future__ import annotations

from benchmarks.common import banner, get_predictor, get_trace, save_result
from repro.core.sched.policies import make_policy
from repro.sim.simulator import SimConfig, Simulator


def main(n_jobs: int = 400, fast: bool = False):
    banner("Table II — preemption under extreme load")
    mp = get_predictor(fast=fast)
    rows = []
    node_counts = [1, 2, 3, 4, 5] if not fast else [2, 4]
    for n in node_counts:
        row = {"nodes": n}
        for tag in ("maestro", "maestro-np"):
            jobs = get_trace(n_jobs, rate=5.0, batch_ratio=0.6, seed=31)
            cfg = SimConfig(nodes_per_cluster=(n,))
            r = Simulator(jobs, make_policy(tag, predictor=mp), cfg).run()
            row[tag] = {"slo": round(r.slo_attainment, 3),
                        "intq_ms": round(r.interactive_queue_delay_s * 1e3, 1)}
        rows.append(row)
        print(f"nodes={n}: preempt slo={row['maestro']['slo']:.2f} "
              f"delay={row['maestro']['intq_ms']:.0f}ms | w/o preempt "
              f"slo={row['maestro-np']['slo']:.2f} "
              f"delay={row['maestro-np']['intq_ms']:.0f}ms")
    # preemption should not lose on SLO and should cut interactive delay
    wins = sum(r["maestro"]["intq_ms"] <= r["maestro-np"]["intq_ms"] * 1.05
               for r in rows)
    assert wins >= len(rows) - 1, rows
    save_result("table2_preemption", rows)
    return rows


if __name__ == "__main__":
    main()
