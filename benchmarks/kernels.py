"""Kernel microbenchmarks: interpret-mode allclose vs oracle + jitted-ref
wall time per call (TPU wall-time is the dry-run roofline's job; this proves
correctness + gives the CPU-reference cost)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import banner, save_result
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssd_chunk import ssd_chunk


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def main(fast: bool = False):
    banner("Kernel validation + reference timings")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    rows = {}

    B, S, H, Hkv, hd = 2, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
    out = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    exp = ref.flash_attention_ref(q, k, v)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(exp))))
    us = _time(jax.jit(ref.flash_attention_ref), q, k, v)
    rows["flash_attention"] = {"max_err": err, "ref_us": round(us, 1)}
    print(f"flash_attention  err={err:.2e}  ref={us:8.1f}us/call")
    assert err < 1e-4

    n_pages, page, slots = 40, 32, 8
    qd = jax.random.normal(ks[3], (B, H, hd), jnp.float32)
    kp = jax.random.normal(ks[4], (n_pages, page, Hkv, hd), jnp.float32)
    vp = jax.random.normal(ks[5], (n_pages, page, Hkv, hd), jnp.float32)
    bt = jax.random.permutation(ks[6], n_pages)[:B * slots] \
        .reshape(B, slots).astype(jnp.int32)
    sl = jnp.array([200, 77], jnp.int32)
    out = paged_attention(qd, kp, vp, bt, sl, page_size=page, interpret=True)
    exp = ref.paged_attention_ref(qd, kp, vp, bt, sl)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(exp))))
    us = _time(jax.jit(ref.paged_attention_ref), qd, kp, vp, bt, sl)
    rows["paged_attention"] = {"max_err": err, "ref_us": round(us, 1)}
    print(f"paged_attention  err={err:.2e}  ref={us:8.1f}us/call")
    assert err < 1e-4

    B2, S2, H2, P2, N2 = 2, 256, 4, 32, 16
    x = jax.random.normal(ks[7], (B2, S2, H2, P2), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B2, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[1], (H2,)) * 0.3)
    Bm = jax.random.normal(ks[2], (B2, S2, H2, N2), jnp.float32)
    Cm = jax.random.normal(ks[3], (B2, S2, H2, N2), jnp.float32)
    out = ssd_chunk(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    exp = ref.ssd_chunk_ref(x, dt, A, Bm, Cm)
    scale = float(np.max(np.abs(np.asarray(exp)))) + 1e-9
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(exp)))) / scale
    us = _time(jax.jit(ref.ssd_chunk_ref), x, dt, A, Bm, Cm)
    rows["ssd_chunk"] = {"max_rel_err": err, "ref_us": round(us, 1)}
    print(f"ssd_chunk        err={err:.2e}  ref={us:8.1f}us/call")
    assert err < 1e-3
    save_result("kernels", rows)
    return rows


if __name__ == "__main__":
    main()
