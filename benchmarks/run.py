"""Benchmark driver — one benchmark per paper table/figure.

  python -m benchmarks.run              # full pass (tens of minutes)
  python -m benchmarks.run --fast       # reduced sizes (CI / smoke)
  python -m benchmarks.run --smoke      # tiny sizes, subset policies (CI)
  python -m benchmarks.run --only table5_memory fig10_activation
  python -m benchmarks.run --smoke --only gateway --backend process
                                        # live gateway on worker processes
  python -m benchmarks.run --smoke --only gateway --backend socket
                                        # live gateway over the framed-TCP
                                        # socket transport (localhost)
  python -m benchmarks.run --smoke --only gateway --clock wall
                                        # wall-clock gateway (real elapsed
                                        # time, inproc vs process fleets)
  python -m benchmarks.run --smoke --only gateway_socket
                                        # socket parity + wall overhead +
                                        # kill-a-worker fault injection
"""
from __future__ import annotations

import argparse
import hashlib
import json
import platform
import subprocess
import time
import traceback

BENCHES = {}

# CI smoke runs one sim policy and one live-gateway policy end-to-end so the
# benchmark entry points can't silently rot
SMOKE_POLICIES = ("fcfs", "maestro")


def _register(mode: str, backend: str = "inproc",
              clock: str = "virtual") -> None:
    from benchmarks import (activation, colocation, decode_horizon,
                            engine_batching, fitness, gateway, kernels,
                            memory, prediction, preemption, prefix_reuse,
                            scheduling, tail_scenarios)
    fast = mode != "full"
    smoke = mode == "smoke"
    if clock == "wall":
        # wall rows are machine-dependent: smoke asserts completion only
        # (max_run_s-capped so a hung fleet fails fast instead of wedging
        # CI); sized runs additionally assert the process-fleet speedup
        gateway_bench = lambda: gateway.wall_main(  # noqa: E731
            n_jobs={"full": 96, "fast": 64, "smoke": 4}[mode],
            rate={"full": 16.0, "fast": 16.0, "smoke": 2.0}[mode],
            max_run_s={"full": 1800.0, "fast": 900.0, "smoke": 300.0}[mode],
            gen_cap={"full": 48, "fast": 48, "smoke": 8}[mode],
            repeats=1 if smoke else 2,
            assert_speedup=not smoke)
    else:
        gateway_bench = lambda: gateway.main(  # noqa: E731
            n_jobs={"full": 240, "fast": 24, "smoke": 5}[mode], fast=fast,
            policies=SMOKE_POLICIES if smoke else None, backend=backend)
    BENCHES.update({
        "gateway": gateway_bench,
        "gateway_socket": lambda: gateway.socket_main(
            n_jobs={"full": 48, "fast": 12, "smoke": 5}[mode],
            fault_jobs=6),
        "decode_horizon": lambda: decode_horizon.main(
            n_jobs={"full": 24, "fast": 12, "smoke": 4}[mode],
            gen_cap={"full": 16, "fast": 12, "smoke": 6}[mode],
            max_new={"full": 96, "fast": 48, "smoke": 12}[mode],
            max_run_s={"full": 1800.0, "fast": 900.0, "smoke": 300.0}[mode],
            repeats=1 if smoke else 2,
            backend=backend,
            assert_speedup=not smoke),
        "engine_batching": lambda: engine_batching.main(
            n_jobs={"full": 32, "fast": 24, "smoke": 4}[mode],
            rate={"full": 8.0, "fast": 8.0, "smoke": 2.0}[mode],
            gen_cap={"full": 24, "fast": 16, "smoke": 6}[mode],
            max_run_s={"full": 1800.0, "fast": 900.0, "smoke": 300.0}[mode],
            repeats=1 if smoke else 2,
            backend=backend,
            assert_speedup=not smoke),
        "tail_scenarios": lambda: tail_scenarios.main(
            n_jobs={"full": 1000, "fast": 150, "smoke": 30}[mode],
            fault_jobs={"full": 48, "fast": 24, "smoke": 10}[mode],
            policies=SMOKE_POLICIES if smoke else None,
            clock=clock,
            max_run_s={"full": 1800.0, "fast": 900.0, "smoke": 300.0}[mode]),
        "prefix_reuse": lambda: prefix_reuse.main(
            n_jobs={"full": 96, "fast": 24, "smoke": 10}[mode], fast=fast,
            backend=backend, include_wall=(mode == "full")),
        "table3_6_7_prediction": lambda: prediction.main(
            n_jobs=800 if fast else 2500),
        "fig7_scheduling": lambda: scheduling.main(
            n_jobs={"full": 600, "fast": 250, "smoke": 250}[mode], fast=fast,
            policies=SMOKE_POLICIES if smoke else None),
        "table2_preemption": lambda: preemption.main(
            n_jobs=200 if fast else 400, fast=fast),
        "table4_colocation": lambda: colocation.main(fast=fast),
        "table5_memory": lambda: memory.main(fast=fast),
        "table8_fitness": lambda: fitness.main(
            n_jobs=250 if fast else 500, fast=fast),
        "fig10_activation": lambda: activation.main(fast=fast),
        "kernels": lambda: kernels.main(fast=fast),
    })


# headline metric per BENCH file (all higher-is-better): a re-run that lands
# >20% below the persisted value prints a loud regression warning BEFORE the
# file is overwritten — the trajectory record stays honest without making
# machine-dependent wall numbers a hard CI gate
HEADLINES = {
    "decode_horizon": "decode_speedup_h8_x",
    "engine_batching": "chunked_speedup_x",
    "prefix_reuse": "prefill_avoided_frac",
}
REGRESSION_FRAC = 0.20


def check_headline_regression(name: str, payload: dict) -> None:
    """Compare a bench payload's headline metric against the persisted
    BENCH_<name>.json (if any) and warn on a >20% drop. Comparison is
    best-effort: missing files, keys or zero baselines are silent."""
    base = name
    for sfx in ("_backend", "_wall", "_process", "_socket"):
        if base.endswith(sfx):
            base = base[:-len(sfx)]
    key = HEADLINES.get(name) or HEADLINES.get(base)
    if key is None or not isinstance(payload, dict):
        return
    from benchmarks.common import RESULTS
    prev_file = RESULTS / f"BENCH_{name}.json"
    if not prev_file.exists():
        return
    try:
        prev = json.loads(prev_file.read_text()).get(key)
    except (json.JSONDecodeError, OSError):
        return
    cur = payload.get(key)
    if not isinstance(prev, (int, float)) or prev <= 0 \
            or not isinstance(cur, (int, float)):
        return
    drop = (prev - cur) / prev
    if drop > REGRESSION_FRAC:
        print(f"[run] WARNING: {name} headline {key} regressed "
              f"{drop:.0%} ({prev} -> {cur}); persisted baseline will be "
              f"overwritten — investigate before trusting the new row")


def repro_stamp(payload: dict) -> dict:
    """Reproducibility stamp for persisted BENCH payloads: the exact source
    revision, the host that produced the row, and a fingerprint of the
    payload's own config scalars (everything but the result rows) — so two
    BENCH files are comparable iff their stamps match."""
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             check=True).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip())
    except Exception:
        sha, dirty = "unknown", False
    cfg = {k: v for k, v in payload.items()
           if not isinstance(v, (list, dict)) or k in ("policies", "zoo")}
    fp = hashlib.sha256(
        json.dumps(cfg, sort_keys=True, default=str).encode()).hexdigest()
    return {"git_sha": sha, "git_dirty": dirty, "host": platform.node(),
            "config_fingerprint": fp[:16]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + policy subset (CI entry-point check)")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--backend", choices=("inproc", "process", "socket"),
                    default="inproc",
                    help="gateway node backend: cooperative in-process "
                         "runtimes (default), one worker process per node "
                         "(pipes), or worker processes over the framed-TCP "
                         "socket transport")
    ap.add_argument("--clock", choices=("virtual", "wall"),
                    default="virtual",
                    help="gateway clock: deterministic virtual ticks "
                         "(default) or real wall time (runs BOTH node "
                         "backends and reports the process-fleet speedup; "
                         "rows land in BENCH_gateway_wall.json)")
    args = ap.parse_args()
    mode = "smoke" if args.smoke else "fast" if args.fast else "full"
    _register(mode, backend=args.backend, clock=args.clock)
    names = args.only or list(BENCHES)
    failures = []
    t_all = time.time()
    for name in names:
        t0 = time.time()
        try:
            payload = BENCHES[name]()
            if payload is not None:
                # machine-readable perf record (e.g. BENCH_gateway.json) so
                # the trajectory is trackable across PRs; non-default node
                # backends and the wall clock get their own files
                # (BENCH_gateway_process.json / BENCH_gateway_wall.json) so
                # they never clobber the virtual in-process baseline record
                from benchmarks.common import save_result
                suffix = ""
                if isinstance(payload, dict):
                    if payload.get("clock", "virtual") == "wall":
                        suffix = "_wall"
                    elif payload.get("node_backend", "inproc") != "inproc":
                        suffix = f"_{payload['node_backend']}"
                        if f"{name}{suffix}" in BENCHES:
                            # a dedicated bench owns that filename (e.g.
                            # gateway_socket): disambiguate the generic
                            # backend-swept rows
                            suffix += "_backend"
                    payload["repro"] = repro_stamp(payload)
                    check_headline_regression(f"{name}{suffix}", payload)
                try:
                    save_result(f"BENCH_{name}{suffix}", payload)
                except TypeError as e:   # non-JSON payload: keep bench green
                    print(f"[run] {name}: payload not serializable ({e})")
            print(f"[run] {name} OK ({time.time()-t0:.0f}s)")
        except Exception as e:
            failures.append((name, e))
            traceback.print_exc()
            print(f"[run] {name} FAILED: {e}")
    print(f"\n[run] {len(names)-len(failures)}/{len(names)} benchmarks OK "
          f"({time.time()-t_all:.0f}s total)")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
