"""Decode-horizon benchmark: fused multi-token decode vs one-sync-per-token.

Sweeps the on-device decode horizon H over {1, 4, 8, 16} and reports, per H:

* **decode tokens/s** — pure-decode wall throughput on a saturated engine
  (every slot decoding, prompts already prefilled, jit warm). H=1 pays one
  host round-trip per token; H>1 runs the whole horizon inside one jitted
  ``fori_loop`` and syncs once per launch.
* **host syncs/token** — ``stat_decode_syncs / stat_decode_tokens``; the
  engine-level restatement of the fused loop (<= 1/H in steady state, since
  one launch can also retire several lanes' tokens).
* **boundary-preemption latency** — ``step()`` wall percentiles in pure
  decode. Preemption (evict/cancel) lands at step boundaries, so the
  in-flight step duration IS the preemption window; the horizon widens it
  by design and this column MEASURES (never asserts) the cost.

Two legs:

* **parity** (virtual clock, deterministic, asserted on every run including
  CI smoke): the live gateway serves the same trace on identical fleets that
  differ only in ``decode_horizon``; per-stage output lengths must match the
  H=1 fleet exactly, and the fleet-level ``host_syncs_per_token`` must not
  exceed 1/H.
* **throughput** (wall, engine-level): sized runs assert decode tokens/s at
  H>=8 is >= 2x the H=1 row (smoke asserts completion only — wall rows may
  never flake CI).

Persisted by ``benchmarks.run`` as ``BENCH_decode_horizon.json``
(schema in docs/BENCHMARKS.md).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import banner, get_trace

HORIZONS = (1, 4, 8, 16)

#: self-attention zoo model the engine leg saturates (the horizon needs
#: pure causal-KV decode; SSM models degrade to H=1 — covered by tests)
MODEL = "qwen3-8b"


# ------------------------------------------------------------ parity (fleet)
def _parity_leg(n_jobs: int, seed: int, gen_cap: int, backend: str,
                max_run_s: float) -> int:
    from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                       jobs_from_trace)
    from repro.serving.gateway import ClusterGateway, GatewayConfig
    from repro.serving.worker import close_fleet
    trace = get_trace(n_jobs, seed=seed, rate=8.0)
    base = None
    base_syncs = None
    for h in HORIZONS:
        mk = lambda c: NodeSpec(c, max_slots=4, hbm_budget=2e9,  # noqa: E731
                                decode_horizon=h)
        spec = ClusterSpec(nodes=(mk(0), mk(1)), model_names=(MODEL,))
        fleet = build_fleet(spec, backend=backend)
        try:
            gw = ClusterGateway(
                fleet, spec.rtt_s, policy="fcfs",
                cfg=GatewayConfig(clock="virtual", node_backend=backend,
                                  max_run_s=max_run_s))
            jobs = jobs_from_trace(trace, n_clusters=spec.n_clusters,
                                   seed=seed, prompt_cap=16, gen_cap=gen_cap)
            m = gw.run(jobs)
            outs = {sid: e.out_len for sid, e in gw.telemetry.events.items()}
        finally:
            close_fleet(fleet)
        assert m.finished_jobs == n_jobs, \
            f"parity/H={h}: {m.finished_jobs}/{n_jobs} ({m.run_outcome})"
        if h == 1:
            base, base_syncs = outs, m.host_syncs_per_token
        else:
            assert outs == base, f"H={h} outputs diverged from H=1"
            # lanes aren't saturated at fleet level (sparse arrivals, short
            # generations), so the strict <= 1/H bound lives in the engine
            # leg; here the fused launches must still strictly beat H=1
            assert m.host_syncs_per_token < base_syncs, \
                f"H={h}: {m.host_syncs_per_token:.4f} syncs/token did not " \
                f"improve on H=1 ({base_syncs:.4f})"
        print(f"[decode-horizon] parity H={h:>2}: {len(outs)} stages, "
              f"syncs/token={m.host_syncs_per_token:.4f}")
    return len(base)


# ------------------------------------------------- throughput (engine, wall)
def _decode_leg(h: int, model, params, *, max_slots: int, max_new: int,
                prompt_len: int, s_max: int, repeats: int) -> Dict:
    import jax
    from repro.core.runtime.accounting import MemoryAccountant
    from repro.serving.engine import Engine, Request

    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, model.cfg.vocab, prompt_len))
               for _ in range(max_slots)]

    def serve():
        eng = Engine(model, params, MemoryAccountant(m_total=2e9),
                     max_slots=max_slots, s_max=s_max, kv_backend="ref",
                     decode_horizon=h)
        for i, p in enumerate(prompts):
            eng.submit(Request(req_id=i, tokens=list(p), max_new=max_new))
        # every step is a preemption boundary, so every step's duration is
        # measured — the first one also carries the (warm, batched) prefill,
        # identical across legs and amortized over max_slots*max_new tokens
        steps = []
        t0 = time.perf_counter()
        while eng.active or eng.waiting:
            s0 = time.perf_counter()
            eng.step()
            steps.append(time.perf_counter() - s0)
        wall = time.perf_counter() - t0
        return eng, wall, steps

    serve()                            # jit warmup (per-Model cache)
    best = None
    for _ in range(max(1, repeats)):
        eng, wall, steps = serve()
        tps = eng.stat_decode_tokens / max(wall, 1e-9)
        if best is None or tps > best["decode_tokens_per_s"]:
            best = {
                "horizon": h,
                "decode_tokens_per_s": round(tps, 1),
                "host_syncs_per_token": round(
                    eng.stat_decode_syncs / max(eng.stat_decode_tokens, 1),
                    4),
                "horizon_launches": eng.stat_horizon_steps,
                "decode_tokens": eng.stat_decode_tokens,
                "step_wall_p50_s": round(float(np.percentile(steps, 50)), 5),
                "step_wall_p95_s": round(float(np.percentile(steps, 95)), 5),
                "decode_wall_s": round(wall, 3),
            }
    return best


def main(n_jobs: int = 12, seed: int = 7, gen_cap: int = 12,
         backend: str = "inproc", max_slots: int = 2, max_new: int = 48,
         prompt_len: int = 8, repeats: int = 2, max_run_s: float = 900.0,
         assert_speedup: bool = True) -> Dict:
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    banner(f"decode-horizon: H sweep {HORIZONS} ({n_jobs} jobs parity, "
           f"{max_slots}x{max_new} decode leg, {backend} fleet)")

    # ---- parity leg: deterministic, asserted on every run
    parity_stages = _parity_leg(n_jobs, seed, gen_cap, backend, max_run_s)

    # ---- throughput leg: saturated pure decode, wall clock
    cfg = get_config(MODEL).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s_max = max(64, prompt_len + max_new + 2)
    rows: List[Dict] = []
    for h in HORIZONS:
        row = _decode_leg(h, model, params, max_slots=max_slots,
                          max_new=max_new, prompt_len=prompt_len,
                          s_max=s_max, repeats=repeats)
        rows.append(row)
        print(f"[decode-horizon] H={h:>2}: "
              f"{row['decode_tokens_per_s']:>8.1f} tok/s  "
              f"syncs/tok={row['host_syncs_per_token']:.4f}  "
              f"step p50={row['step_wall_p50_s']*1e3:.1f}ms "
              f"p95={row['step_wall_p95_s']*1e3:.1f}ms")

    by_h = {r["horizon"]: r for r in rows}
    speedup8 = (by_h[8]["decode_tokens_per_s"]
                / max(by_h[1]["decode_tokens_per_s"], 1e-9))
    speedup16 = (by_h[16]["decode_tokens_per_s"]
                 / max(by_h[1]["decode_tokens_per_s"], 1e-9))
    print(f"[decode-horizon] speedup vs H=1: "
          f"H=8 {speedup8:.2f}x, H=16 {speedup16:.2f}x")
    for h in HORIZONS[1:]:
        assert by_h[h]["host_syncs_per_token"] <= 1.0 / h + 1e-9, \
            f"H={h} syncs/token {by_h[h]['host_syncs_per_token']} > 1/{h}"
    if assert_speedup:
        # the acceptance bar for the fused decode loop (sized runs only)
        assert speedup8 >= 2.0, \
            f"H=8 decode speedup {speedup8:.2f}x < 2x ({by_h})"

    return {
        "n_jobs": n_jobs,
        "gen_cap": gen_cap,
        "horizons": list(HORIZONS),
        "model": MODEL,
        "max_slots": max_slots,
        "max_new": max_new,
        "prompt_len": prompt_len,
        "node_backend": backend,
        "repeats": repeats,
        "parity_stages": parity_stages,
        "decode_speedup_h8_x": round(speedup8, 2),
        "decode_speedup_h16_x": round(speedup16, 2),
        "host_syncs_per_token_h8": by_h[8]["host_syncs_per_token"],
        "rows": rows,
    }


if __name__ == "__main__":
    main()
