"""Table VIII: node-fitness scoring on interactive queueing delay —
Baseline (load-balancing) vs BinPack-only (gamma=0) vs Maestro-Aff
(gamma=0.25) on the hybrid 3-local + 2-remote topology."""
from __future__ import annotations

from benchmarks.common import banner, get_predictor, get_trace, save_result
from repro.core.sched.policies import make_policy
from repro.core.topology import HYBRID_RTT as RTT
from repro.sim.simulator import SimConfig, Simulator


def main(n_jobs: int = 500, fast: bool = False):
    banner("Table VIII — cross-cluster fitness scoring")
    mp = get_predictor(fast=fast)
    cfg = SimConfig(nodes_per_cluster=(2, 1, 2))
    rates = [0.5, 1.0, 2.0] if not fast else [1.0]
    rows = []
    for rate in rates:
        row = {"rate": rate}
        for name, tag in (("baseline-lb", "baseline"),
                          ("binpack", "binpack"),
                          ("maestro-aff", "maestro-aff")):
            jobs = get_trace(n_jobs, rate=rate, seed=41)
            r = Simulator(jobs, make_policy(name, predictor=mp),
                          cfg, rtt=RTT).run()
            row[tag] = round(r.interactive_queue_delay_s, 3)
        rows.append(row)
        print(f"rate={rate}: baseline={row['baseline']:.3f}s "
              f"binpack={row['binpack']:.3f}s "
              f"maestro-aff={row['maestro-aff']:.3f}s")
    # ordering claim: maestro-aff beats baseline at low/mid load and on
    # average; at saturation (rate 2.0) all policies converge/queue-dominate
    # (the paper's own gaps shrink to ~8% there)
    import numpy as _np
    for row in rows:
        if row["rate"] <= 1.0:
            assert row["maestro-aff"] <= row["baseline"] * 1.10, row
    assert (_np.mean([r["maestro-aff"] for r in rows])
            <= _np.mean([r["baseline"] for r in rows])), rows
    save_result("table8_fitness", rows)
    return rows


if __name__ == "__main__":
    main()
