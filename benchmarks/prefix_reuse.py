"""Workflow-aware prefix-reuse benchmark: cross-stage KV sharing, live.

Serves an agent-TEAM trace (``generate_team_trace`` — conversation-style
workflows whose prompts embed the team system prompt and every upstream
turn) through ``ClusterGateway`` on a prefix-cache-enabled fleet, under

- ``maestro`` with the fleet cache DISABLED (prefill baseline),
- ``maestro`` with the cache enabled (reuse without routing awareness),
- ``maestro-prefix`` (reuse + prefix-affinity routing: stages are steered
  toward the node already holding their prefix chain).

Headline columns: ``prefill_avoided_frac`` (prompt tokens served from
cached prefix pages over total prompt tokens) and the interactive queue
delay.  Acceptance: maestro-prefix avoids >= 30% of prefill tokens and at
least as many as cache-enabled maestro, with no interactive-latency
regression.  On the virtual clock every engine step costs one tick
regardless of prefill length, so the latency delta is structurally ~0
there — the avoided-token fraction is the reuse evidence, and wall-clock
runs (``include_wall=True``) are where the compute saving becomes time.

Persisted by ``benchmarks.run`` as ``BENCH_prefix_reuse.json``
(``BENCH_prefix_reuse_process.json`` for the worker-process fleet).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from benchmarks.common import banner, get_predictor
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet

#: team prompts reach ~150 tokens (4-block chains); the engine window must
#: hold prompt + decode budget
_S_MAX = 192

MIN_AVOIDED_FRAC = 0.30


def _spec(prefix_cache: bool) -> ClusterSpec:
    return ClusterSpec(nodes=(
        NodeSpec(0, max_slots=2, s_max=_S_MAX, prefix_cache=prefix_cache),
        NodeSpec(0, max_slots=2, s_max=_S_MAX, hbm_budget=0.8e9,
                 prefix_cache=prefix_cache),
        NodeSpec(1, max_slots=2, s_max=_S_MAX, prefix_cache=prefix_cache)))


def _run_row(trace, pred, policy: str, prefix_cache: bool, backend: str,
             clock: str, seed: int, gen_cap: int,
             max_run_s: Optional[float] = None) -> Dict:
    spec = _spec(prefix_cache)
    zoo, host = (None, None) if backend == "process" \
        else build_zoo(spec.model_names)
    fleet = build_fleet(spec, zoo=zoo, host=host, backend=backend)
    jobs = jobs_from_trace(trace, n_clusters=spec.rtt_s.shape[0],
                           seed=seed, gen_cap=gen_cap)
    t0 = time.time()
    try:
        gw = ClusterGateway(fleet, spec.rtt_s, predictor=pred, policy=policy,
                            cfg=GatewayConfig(node_backend=backend,
                                              clock=clock,
                                              max_run_s=max_run_s))
        if clock == "wall":
            gw.warmup()
        m = gw.run(jobs)
    finally:
        close_fleet(fleet)
    wall = time.time() - t0
    assert m.finished_jobs > 0, f"{policy}: no jobs finished"
    row = m.row()
    row["prefix_cache"] = prefix_cache
    row["wall_s"] = round(wall, 1)
    row["prefill_avoided_frac"] = (
        m.prefill_tokens_avoided / max(m.prefill_tokens_total, 1))
    print(f"[prefix_reuse] {policy:>14} cache={'on ' if prefix_cache else 'off'}"
          f" {clock}/{backend}: avoided="
          f"{m.prefill_tokens_avoided}/{m.prefill_tokens_total} "
          f"({row['prefill_avoided_frac']:.0%}) "
          f"int_qd={m.interactive_queue_delay_s:.2f}s "
          f"p99={m.p99_latency_s:.2f}s cow={m.prefix_stats.get('cow_copies', 0):.0f} "
          f"fin={m.finished_jobs} ({wall:.0f}s wall)")
    return row


def main(n_jobs: int = 48, rate: float = 2.0, seed: int = 17,
         fast: bool = False, gen_cap: int = 8, backend: str = "inproc",
         include_wall: bool = False) -> Dict:
    from repro.data.tracegen import generate_team_trace
    banner(f"prefix_reuse: cross-stage KV sharing ({n_jobs} team jobs, "
           f"{backend} nodes)")
    pred = get_predictor(n_jobs=800, fast=True)
    trace = generate_team_trace(n_jobs, rate=rate, seed=seed)

    rows: List[Dict] = [
        _run_row(trace, pred, "maestro", False, backend, "virtual",
                 seed, gen_cap),
        _run_row(trace, pred, "maestro", True, backend, "virtual",
                 seed, gen_cap),
        _run_row(trace, pred, "maestro-prefix", True, backend, "virtual",
                 seed, gen_cap),
    ]
    if include_wall and not fast:
        rows += [_run_row(trace, pred, p, True, backend, "wall", seed,
                          gen_cap, max_run_s=900.0)
                 for p in ("maestro", "maestro-prefix")]

    by = {(r["policy"], r["prefix_cache"]): r for r in rows
          if r["clock"] == "virtual"}
    base = by[("maestro", False)]
    cached = by[("maestro", True)]
    affin = by[("maestro-prefix", True)]
    assert base["prefill_tokens_avoided"] == 0, \
        "disabled cache avoided prefill tokens"
    frac = affin["prefill_avoided_frac"]
    assert frac >= MIN_AVOIDED_FRAC, \
        f"maestro-prefix avoided only {frac:.0%} of prefill tokens " \
        f"(need >= {MIN_AVOIDED_FRAC:.0%})"
    # affinity routing should match or beat unaware routing; allow a small
    # tolerance — placement changes shift WHICH stages coincide in a batch,
    # so tiny smoke runs can tie within a couple of pages either way
    assert frac >= cached["prefill_avoided_frac"] - 0.03, \
        "prefix-affinity routing avoided materially fewer tokens than " \
        f"plain maestro ({frac:.0%} vs {cached['prefill_avoided_frac']:.0%})"
    # reuse must never cost interactive latency (virtual clock: the stage
    # timeline is prefill-length-independent, so this is ~an equality)
    delta = (cached["interactive_queue_delay_s"]
             - affin["interactive_queue_delay_s"])
    assert delta >= -1e-6, \
        f"maestro-prefix regressed interactive queue delay by {-delta:.3f}s"
    print(f"[prefix_reuse] maestro-prefix: {frac:.0%} prefill avoided "
          f"(cache-only maestro {cached['prefill_avoided_frac']:.0%}), "
          f"interactive delay delta {delta:+.3f}s")
    return {
        "n_jobs": n_jobs,
        "n_stages": sum(len(j.stages) for j in trace),
        "rate_jobs_per_s": rate,
        "gen_cap": gen_cap,
        "s_max": _S_MAX,
        "node_backend": backend,
        "policies": ["maestro", "maestro-prefix"],
        "min_avoided_frac": MIN_AVOIDED_FRAC,
        "prefill_avoided_frac": frac,
        "prefill_avoided_frac_cache_only": cached["prefill_avoided_frac"],
        "interactive_qd_delta_s": delta,
        "rows": rows,
    }


if __name__ == "__main__":
    main(n_jobs=12, fast=True)
