"""Tables III / VI / VII: tool-intent classification, output-length
regression, and the prediction-module ablation — Maestro-Pred vs Linear /
BERT-MLP / Magnus, plus MLP-variant neural baselines for the classifier."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import banner, get_trace, save_result
from repro.core.predictor import (BertMLPBaseline, GBDT, GBDTConfig,
                                  IsotonicCalibrator, LinearBaseline, MLP,
                                  MaestroPred, MagnusBaseline,
                                  PredictorConfig, classification_metrics,
                                  regression_metrics)
from repro.core.predictor.features import featurize_batch
from repro.data.tracegen import stratified_temporal_split


def _data(n_jobs: int):
    jobs = get_trace(n_jobs)
    train, test = stratified_temporal_split(jobs)
    y_tr = np.array([s.true_len for s in train], float)
    y_te = np.array([s.true_len for s in test], float)
    t_tr = np.array([float(s.tool_call) for s in train])
    t_te = np.array([float(s.tool_call) for s in test])
    return train, test, y_tr, y_te, t_tr, t_te


def bench_tool_intent(n_jobs: int = 2500):
    """Table III: classifier comparison (AUC / F1 / Acc / MSE / logloss)."""
    banner("Table III — tool-intent classification")
    train, test, _, _, t_tr, t_te = _data(n_jobs)
    X_tr = featurize_batch([s.obs for s in train])
    X_te = featurize_batch([s.obs for s in test])
    n_val = max(1, len(X_tr) // 7)
    rows = {}

    m = GBDT(GBDTConfig(objective="logloss", n_trees=120, max_leaves=31)).fit(
        X_tr[:-n_val], t_tr[:-n_val], X_tr[-n_val:], t_tr[-n_val:])
    cal = IsotonicCalibrator().fit(m.predict(X_tr[-n_val:]), t_tr[-n_val:])
    rows["Maestro-Pred"] = classification_metrics(
        t_te, cal.transform(m.predict(X_te)))

    for name, hidden in (("MLP_64_32", (64, 32)), ("MLP_128_64", (128, 64)),
                         ("MLP_3layer", (128, 64, 32))):
        mlp = MLP(hidden=hidden, classifier=True, epochs=30).fit(X_tr, t_tr)
        rows[name] = classification_metrics(t_te, mlp.predict(X_te))

    for name, m_ in rows.items():
        print(f"{name:14s} auc={m_['auc']:.4f} f1={m_['f1_macro']:.4f} "
              f"acc={m_['acc']:.4f} mse={m_['mse']:.4f} "
              f"logloss={m_['logloss']:.4f} negrec={m_['neg_recall']:.4f}")
    best_auc = max(rows.values(), key=lambda r: r["auc"])
    assert rows["Maestro-Pred"]["auc"] >= best_auc["auc"] - 0.02
    save_result("table3_tool_intent", rows)
    return rows


def bench_length(n_jobs: int = 2500):
    """Table VI: output-length MAE / R^2 across predictors."""
    banner("Table VI — output-length prediction")
    train, test, y_tr, y_te, t_tr, _ = _data(n_jobs)
    obs_tr = [s.obs for s in train]
    obs_te = [s.obs for s in test]
    rows = {}
    t0 = time.time()
    mp = MaestroPred().fit(obs_tr, y_tr, t_tr)
    rows["Maestro-Pred"] = regression_metrics(
        y_te, mp.predict(obs_te)["length"])
    rows["Maestro-Pred"]["fit_s"] = round(time.time() - t0, 1)
    rows["Magnus"] = regression_metrics(
        y_te, MagnusBaseline().fit(obs_tr, y_tr).predict(obs_te)["length"])
    rows["BERT-MLP"] = regression_metrics(
        y_te, BertMLPBaseline().fit(obs_tr, y_tr).predict(obs_te)["length"])
    rows["Linear"] = regression_metrics(
        y_te, LinearBaseline().fit(obs_tr, y_tr).predict(obs_te)["length"])
    for name, m in rows.items():
        print(f"{name:14s} MAE={m['mae']:8.2f}  R2={m['r2']:+.4f}")
    mae_cut = 1 - rows["Maestro-Pred"]["mae"] / rows["Magnus"]["mae"]
    print(f"MAE reduction vs Magnus: {mae_cut*100:.1f}% (paper: 19.2%)")
    print("note: on this synthetic trace tool-intent is largely recoverable"
          " from structured features, so the single-stage GBDT (Magnus) is"
          " near-parity; the two-phase gain concentrates in Table III's"
          " calibration (logloss) and the ablation (Table VII)")
    rows["mae_cut_vs_magnus_pct"] = mae_cut * 100
    # reproduction claim: Maestro-Pred at or near the best regressor, and the
    # GBDT family far ahead of the neural/linear baselines
    assert rows["Maestro-Pred"]["mae"] <= rows["Magnus"]["mae"] * 1.06
    assert rows["Maestro-Pred"]["mae"] < rows["BERT-MLP"]["mae"]
    assert rows["Linear"]["r2"] < rows["Maestro-Pred"]["r2"]
    save_result("table6_length", rows)
    return rows


def bench_ablation(n_jobs: int = 2500):
    """Table VII: w/o classifier (C) and w/o semantic features (BERT)."""
    banner("Table VII — prediction ablation")
    train, test, y_tr, y_te, t_tr, _ = _data(n_jobs)
    obs_tr = [s.obs for s in train]
    obs_te = [s.obs for s in test]
    cot_te = np.array([s.obs.cot for s in test])
    variants = {
        "Full": PredictorConfig(),
        "w/o C": PredictorConfig(use_classifier=False),
        "w/o BERT": PredictorConfig(use_semantic=False),
    }
    rows = {}
    for name, cfg in variants.items():
        mp = MaestroPred(cfg).fit(obs_tr, y_tr, t_tr)
        pred = mp.predict(obs_te)["length"]
        m = regression_metrics(y_te, pred)
        m["mae_cot"] = regression_metrics(
            y_te[cot_te], pred[cot_te])["mae"] if cot_te.any() else 0.0
        m["mae_noncot"] = regression_metrics(
            y_te[~cot_te], pred[~cot_te])["mae"]
        rows[name] = m
        print(f"{name:9s} MAE={m['mae']:8.2f} R2={m['r2']:+.4f} "
              f"MAE(CoT)={m['mae_cot']:8.2f} MAE(non-CoT)={m['mae_noncot']:8.2f}")
    assert rows["Full"]["r2"] >= rows["w/o BERT"]["r2"]
    save_result("table7_ablation", rows)
    return rows


def main(n_jobs: int = 2500):
    bench_tool_intent(n_jobs)
    bench_length(n_jobs)
    bench_ablation(n_jobs)


if __name__ == "__main__":
    main()
