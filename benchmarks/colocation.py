"""Table IV: Travel Assistant completion time vs GPU budget — Maestro's
hierarchical residency (sleeping keeps warm contexts; weights hot in host
RAM) vs QLM-style process-level switching (one engine owns a GPU; a model
switch is a full engine restart: weight load from disk + engine init/CUDA-
graph capture) vs exclusive deployment (enough GPUs for no switching).

Workflow: Table IV's six LLM invocations across three models (4B planner/
solver/chat, 0.6B tool calls, 14B writer), serial.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import banner, save_result
from repro.core.predictor.cost_model import HardwareSpec
from repro.core.runtime.residency import HierarchicalResidency, ModelState
from repro.data.apps import APPS, MODELS
from repro.sim.simulator import default_profiles

HW = HardwareSpec(name="a100-40g", peak_flops=312e12, hbm_bw=1555e9,
                  hbm_capacity=40e9, host_link_bw=25e9)
ENGINE_INIT_S = 15.0     # process start + allocator + CUDA-graph capture
GPU_BUDGET = 36e9


def _travel_stages():
    app = next(a for a in APPS if a.name == "travel_assistant")
    return [(MODELS[s.model_id], s.prompt_base,
             s.tool_len if s.p_tool > 0.5 else s.base_len * 1.5)
            for s in app.stages]


def _run_qlm(n_gpus: int, profiles) -> float:
    """One resident engine per GPU; switching = restart (disk + init)."""
    owner: List[str] = [""] * n_gpus
    lru: List[int] = [0] * n_gpus
    total, tick = 0.0, 0
    for model, p_len, out_len in _travel_stages():
        tick += 1
        if model in owner:
            g = owner.index(model)
        else:
            g = min(range(n_gpus),
                    key=lambda i: (owner[i] != "", lru[i]))
            total += (profiles[model].weight_bytes / HW.disk_bw
                      + ENGINE_INIT_S)
            owner[g] = model
        lru[g] = tick
        total += profiles[model].t_exec(p_len, out_len)
    return total


def _run_maestro(n_gpus: int, profiles) -> float:
    """Hierarchical residency: weights cached in host RAM, sleeping models
    keep their device context; eviction is graceful (Algorithm 1)."""
    nodes = [HierarchicalResidency(profiles, c_gpu=GPU_BUDGET, c_cpu=512e9,
                                   c_disk=2e12, hw=HW)
             for _ in range(n_gpus)]
    for node in nodes:   # weights staged in host RAM (paper's deployment)
        for m, prof in profiles.items():
            node.state[m] = ModelState.CPU
            node.lru["cpu"][m] = prof.weight_bytes
    total = 0.0
    for model, p_len, out_len in _travel_stages():
        g = min(range(n_gpus),
                key=lambda i: nodes[i].activation_latency(model))
        ok, t_act = nodes[g].ensure_gpu(model)
        assert ok
        total += t_act + profiles[model].t_exec(p_len, out_len)
    return total


def main(fast: bool = False):
    banner("Table IV — Travel Assistant completion vs GPU budget")
    profiles = default_profiles(HW)
    rows: Dict[str, List[float]] = {"maestro": [], "qlm": []}
    for n in (1, 2, 3):
        rows["maestro"].append(round(_run_maestro(n, profiles), 1))
        rows["qlm"].append(round(_run_qlm(n, profiles), 1))
    print(f"{'method':9s}  1 GPU      2 GPUs     3 GPUs   (seconds)")
    for pol, vals in rows.items():
        print(f"{pol:9s}  " + "  ".join(f"{v:8.1f}" for v in vals))
    cut1 = 1 - rows["maestro"][0] / rows["qlm"][0]
    cut2 = 1 - rows["maestro"][1] / rows["qlm"][1]
    print(f"completion cut vs QLM: 1 GPU {cut1*100:.1f}% (paper 70.0%), "
          f"2 GPUs {cut2*100:.1f}% (paper 38.9%)")
    assert rows["maestro"][0] < rows["qlm"][0]
    assert rows["maestro"][1] < rows["qlm"][1]
    # with enough GPUs both match exclusive deployment
    assert abs(rows["maestro"][2] - rows["qlm"][2]) / rows["qlm"][2] < 0.65
    save_result("table4_colocation", {**rows,
                                      "cut_1gpu_pct": cut1 * 100,
                                      "cut_2gpu_pct": cut2 * 100})
    return rows


if __name__ == "__main__":
    main()
