"""Table V: memory accounting under five-model colocation on one A100-40G —
virtual KV budgets, overcommit ratio (paper: 3.05x) and the KV-reservation
HBM saving (paper: 67.2%)."""
from __future__ import annotations

from benchmarks.common import banner, save_result
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool

# Table V inputs: (model, CUDA-graph/warm-context MB, weight GB)
MODELS_V = [
    ("qwen3-0.6b", 194, 1.12),
    ("qwen3-1.7b", 194, 3.21),
    ("qwen3-4b", 256, 7.55),
    ("qwen3-8b", 245, 15.27),
    ("qwen3-14b", 286, 27.52),
]
HBM = 40e9
UTIL = 0.886     # vLLM-style gpu-memory-utilization sizing


def main(fast: bool = False):
    banner("Table V — five-model colocation memory accounting")
    acc = MemoryAccountant(m_total=HBM, m_other=0.0)
    pool = VirtualKVPool(acc, page_bytes=2 << 20, page_tokens=16)
    rows = []
    for name, ctx_mb, w_gb in MODELS_V:
        # each model's virtual KV budget is sized as if it owned the GPU
        virt = UTIL * HBM - w_gb * 1e9 - ctx_mb * 1e6
        pool.set_virtual_budget(name, virt)
        rows.append({"model": name, "ctx_mb": ctx_mb, "weights_gb": w_gb,
                     "virtual_kv_gb": round(virt / 1e9, 2)})
        print(f"{name:12s} ctx={ctx_mb:4d}MB weights={w_gb:6.2f}GB "
              f"virtual-KV={virt/1e9:6.2f}GB")
    total_virtual = pool.virtual_total()
    overcommit = total_virtual / HBM
    saving = 1 - HBM / total_virtual
    ctx_total = sum(m[1] for m in MODELS_V) / 1e3
    print(f"total virtual KV = {total_virtual/1e9:.1f}GB on a 40GB GPU")
    print(f"overcommit ratio = {overcommit:.2f}x (paper: 3.05x)")
    print(f"KV-reservation HBM saving = {saving*100:.1f}% (paper: 67.2%)")
    print(f"warm contexts total = {ctx_total:.2f}GB (paper: ~1.15GB)")
    assert 2.5 <= overcommit <= 3.6
    assert 0.60 <= saving <= 0.72

    # safety: physical admission still enforced under the virtual budgets
    acc.register_weights("qwen3-0.6b", 1.12e9)
    acc.register_context("qwen3-0.6b", 194e6)
    granted = 0
    sid = 0
    while pool.alloc_seq(sid, "qwen3-0.6b", 4096):
        granted += 1
        sid += 1
        if granted > 10_000:
            break
    assert acc.check_invariant()
    assert acc.m_kv <= HBM
    print(f"physical admission stopped at {acc.m_kv/1e9:.1f}GB KV "
          f"({granted} x 4k-token seqs) — no OOM possible")
    save_result("table5_memory", {
        "rows": rows, "total_virtual_gb": total_virtual / 1e9,
        "overcommit_x": overcommit, "saving_pct": saving * 100,
        "ctx_total_gb": ctx_total})


if __name__ == "__main__":
    main()
