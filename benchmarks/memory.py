"""Table V: memory accounting under five-model colocation on one A100-40G —
virtual KV budgets, overcommit ratio (paper: 3.05x) and the KV-reservation
HBM saving (paper: 67.2%) — followed by the same regime exercised against the
PHYSICAL paged arena (`repro.serving.kv_arena`): pool grants mirrored 1:1
onto array-backed plane rows, with peak physical pages and plane utilization
reported into ``BENCH_table5_memory.json``."""
from __future__ import annotations

from benchmarks.common import banner
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool
from repro.serving.kv_arena import KVArena

# Table V inputs: (model, CUDA-graph/warm-context MB, weight GB)
MODELS_V = [
    ("qwen3-0.6b", 194, 1.12),
    ("qwen3-1.7b", 194, 3.21),
    ("qwen3-4b", 256, 7.55),
    ("qwen3-8b", 245, 15.27),
    ("qwen3-14b", 286, 27.52),
]
HBM = 40e9
UTIL = 0.886     # vLLM-style gpu-memory-utilization sizing


def _arena_exercise(fast: bool = False) -> dict:
    """Drive the physical arena the way colocated engines do: two models of
    identical KV geometry interleaving pages in ONE plane, grants flowing
    through per-model pools, alloc/free churn, then full release."""
    banner("physical paged-KV arena — pool grants against real storage")
    page_tokens = 16
    n_layers, hkv, hd = 4, 2, 64                 # small but real geometry
    alpha = n_layers * 2 * hkv * hd * 2          # bf16 bytes/token
    acc = MemoryAccountant(m_total=8 << 20)
    arena = KVArena(page_tokens=page_tokens)
    bindings = {}
    for name in ("colo-a", "colo-b"):
        pool = VirtualKVPool(acc, page_bytes=alpha * page_tokens,
                             page_tokens=page_tokens)
        pool.set_virtual_budget(name, 4 * acc.m_total)   # 4x overcommitted
        bindings[name] = arena.register(
            name, pool, s_max=512, n_layers=n_layers, n_kv_heads=hkv,
            head_dim=hd, dtype="bfloat16")
    n_seqs = 16 if fast else 64
    sid = 0
    live = []
    for i in range(n_seqs):
        b = bindings["colo-a" if i % 2 == 0 else "colo-b"]
        if not b.alloc_seq(sid, b.name, tokens=48 + 16 * (i % 5)):
            break
        live.append((b, sid))
        sid += 1
        if i % 3 == 2:                           # churn: free the oldest
            ob, osid = live.pop(0)
            ob.free_seq(osid)
        assert arena.check_mirror(), "pool<->arena mirror broken"
        assert acc.check_invariant()
    grew = [b.ensure_tokens(s, 200) for b, s in live[:4]]
    assert all(grew) and arena.check_mirror()
    stats = arena.stats()
    for b, s in live:
        b.free_seq(s)
    assert arena.check_mirror() and arena.mapped_pages() == 0
    assert acc.m_kv == 0.0
    virt = sum(b.pool.virtual_total() for b in bindings.values())
    overcommit = virt / max(arena.peak_mapped_bytes, 1.0)
    print(f"planes={stats['planes']} (two models share one geometry plane)")
    print(f"peak physical pages={stats['peak_mapped_pages']} "
          f"({stats['peak_mapped_bytes']/1e6:.1f}MB) "
          f"utilization={stats['utilization']:.2f}")
    print(f"virtual-over-peak-physical overcommit = {overcommit:.2f}x; "
          f"everything reclaimed (m_kv=0)")
    assert stats["planes"] == 1
    assert overcommit > 1.0
    return {"peak_physical_pages": stats["peak_mapped_pages"],
            "peak_physical_bytes": stats["peak_mapped_bytes"],
            "plane_utilization": stats["utilization"],
            "physical_overcommit_x": overcommit}


def main(fast: bool = False):
    banner("Table V — five-model colocation memory accounting")
    acc = MemoryAccountant(m_total=HBM, m_other=0.0)
    pool = VirtualKVPool(acc, page_bytes=2 << 20, page_tokens=16)
    rows = []
    for name, ctx_mb, w_gb in MODELS_V:
        # each model's virtual KV budget is sized as if it owned the GPU
        virt = UTIL * HBM - w_gb * 1e9 - ctx_mb * 1e6
        pool.set_virtual_budget(name, virt)
        rows.append({"model": name, "ctx_mb": ctx_mb, "weights_gb": w_gb,
                     "virtual_kv_gb": round(virt / 1e9, 2)})
        print(f"{name:12s} ctx={ctx_mb:4d}MB weights={w_gb:6.2f}GB "
              f"virtual-KV={virt/1e9:6.2f}GB")
    total_virtual = pool.virtual_total()
    overcommit = total_virtual / HBM
    saving = 1 - HBM / total_virtual
    ctx_total = sum(m[1] for m in MODELS_V) / 1e3
    print(f"total virtual KV = {total_virtual/1e9:.1f}GB on a 40GB GPU")
    print(f"overcommit ratio = {overcommit:.2f}x (paper: 3.05x)")
    print(f"KV-reservation HBM saving = {saving*100:.1f}% (paper: 67.2%)")
    print(f"warm contexts total = {ctx_total:.2f}GB (paper: ~1.15GB)")
    assert 2.5 <= overcommit <= 3.6
    assert 0.60 <= saving <= 0.72

    # safety: physical admission still enforced under the virtual budgets
    acc.register_weights("qwen3-0.6b", 1.12e9)
    acc.register_context("qwen3-0.6b", 194e6)
    granted = 0
    sid = 0
    while pool.alloc_seq(sid, "qwen3-0.6b", 4096):
        granted += 1
        sid += 1
        if granted > 10_000:
            break
    assert acc.check_invariant()
    assert acc.m_kv <= HBM
    print(f"physical admission stopped at {acc.m_kv/1e9:.1f}GB KV "
          f"({granted} x 4k-token seqs) — no OOM possible")

    arena = _arena_exercise(fast=fast)
    # persisted by benchmarks.run as BENCH_table5_memory.json (single source)
    return {
        "rows": rows, "total_virtual_gb": total_virtual / 1e9,
        "overcommit_ratio": overcommit,
        "saving_pct": saving * 100,
        "ctx_total_gb": ctx_total,
        **arena,
    }


if __name__ == "__main__":
    main()
