"""Fig. 7: SLO attainment / mean latency / interactive queueing delay across
arrival rates and batch ratios, for EVERY policy in the unified registry
(fcfs / least-loaded / edf / oracle-srtf / maestro / maestro-np /
baseline-lb / binpack / maestro-aff) on the trace-driven simulator."""
from __future__ import annotations

from typing import Optional, Sequence

from benchmarks.common import banner, get_predictor, get_trace, save_result
from repro.core.sched.policies import make_policy, registered_policies
from repro.sim.simulator import SimConfig, Simulator


def main(n_jobs: int = 600, fast: bool = False,
         policies: Optional[Sequence[str]] = None):
    banner("Fig. 7 — scheduling across arrival rates x batch ratios")
    names = tuple(policies) if policies else registered_policies()
    mp = get_predictor(n_jobs=800 if fast else 2500, fast=fast)
    rates = [0.4, 1.0, 2.0] if not fast else [2.0]
    ratios = [0.2, 0.5, 0.8] if not fast else [0.8]
    cfg = SimConfig(nodes_per_cluster=(2, 2, 1))
    table = []
    for rate in rates:
        for ratio in ratios:
            row = {"rate": rate, "batch_ratio": ratio}
            for name in names:
                jobs = get_trace(n_jobs, rate=rate, batch_ratio=ratio,
                                 seed=21)
                r = Simulator(jobs, make_policy(name, predictor=mp),
                              cfg).run()
                assert r.finished_jobs > 0, f"{name}: no jobs finished"
                row[r.policy] = {
                    "slo": round(r.slo_attainment, 3),
                    "lat": round(r.mean_latency_s, 1),
                    "intq": round(r.interactive_queue_delay_s, 2)}
            table.append(row)
            print(f"rate={rate} ratio={ratio}: " + "  ".join(
                f"{k}={v['slo']:.2f}/{v['intq']:.2f}s"
                for k, v in row.items() if isinstance(v, dict)))
    # headline check: high-contention corner
    hi = table[-1]
    payload = {"table": table, "policies": list(names)}
    if "maestro" in hi and "fcfs" in hi:
        # headline claim: maestro cuts interactive queueing delay under
        # contention without giving up SLO attainment (noise tolerance)
        assert hi["maestro"]["intq"] <= hi["fcfs"]["intq"], hi
        assert hi["maestro"]["slo"] >= hi["fcfs"]["slo"] - 0.03, hi
    if "maestro" in hi and "edf" in hi:
        gain = (hi["maestro"]["slo"] - hi["edf"]["slo"]) * 100
        intq_cut = 1 - hi["maestro"]["intq"] / max(hi["edf"]["intq"], 1e-9)
        print(f"high-contention SLO gain over EDF: {gain:+.1f}pp "
              f"(paper: +23.6pp)")
        print(f"interactive queueing delay cut vs EDF: {intq_cut*100:.1f}% "
              f"(paper: 84.8%)")
        payload["slo_gain_vs_edf_pp"] = gain
        payload["intq_cut_vs_edf_pct"] = intq_cut * 100
    save_result("fig7_scheduling", payload)
    return payload


if __name__ == "__main__":
    main()
