"""Fig. 7: SLO attainment / mean latency / interactive queueing delay across
arrival rates and batch ratios, FCFS vs EDF vs Maestro (vs Oracle-SRTF)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import banner, get_predictor, get_trace, save_result
from repro.sim.policies import EDF, FCFS, Maestro, OracleSRTF
from repro.sim.simulator import SimConfig, Simulator


def main(n_jobs: int = 600, fast: bool = False):
    banner("Fig. 7 — scheduling across arrival rates x batch ratios")
    mp = get_predictor(fast=fast)
    rates = [0.4, 1.0, 2.0] if not fast else [2.0]
    ratios = [0.2, 0.5, 0.8] if not fast else [0.8]
    cfg = SimConfig(nodes_per_cluster=(2, 2, 1))
    table = []
    for rate in rates:
        for ratio in ratios:
            row = {"rate": rate, "batch_ratio": ratio}
            for mk in (lambda: FCFS(), lambda: EDF(),
                       lambda: Maestro(mp), lambda: OracleSRTF()):
                jobs = get_trace(n_jobs, rate=rate, batch_ratio=ratio,
                                 seed=21)
                r = Simulator(jobs, mk(), cfg).run()
                row[r.policy] = {
                    "slo": round(r.slo_attainment, 3),
                    "lat": round(r.mean_latency_s, 1),
                    "intq": round(r.interactive_queue_delay_s, 2)}
            table.append(row)
            print(f"rate={rate} ratio={ratio}: " + "  ".join(
                f"{k}={v['slo']:.2f}/{v['intq']:.2f}s"
                for k, v in row.items() if isinstance(v, dict)))
    # headline check: high-contention corner
    hi = table[-1]
    gain = (hi["maestro"]["slo"] - hi["edf"]["slo"]) * 100
    intq_cut = 1 - hi["maestro"]["intq"] / max(hi["edf"]["intq"], 1e-9)
    print(f"high-contention SLO gain over EDF: {gain:+.1f}pp (paper: +23.6pp)")
    print(f"interactive queueing delay cut vs EDF: {intq_cut*100:.1f}% "
          f"(paper: 84.8%)")
    assert hi["maestro"]["slo"] >= hi["fcfs"]["slo"]
    save_result("fig7_scheduling", {"table": table,
                                    "slo_gain_vs_edf_pp": gain,
                                    "intq_cut_vs_edf_pct": intq_cut * 100})
    return table


if __name__ == "__main__":
    main()
