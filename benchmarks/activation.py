"""Fig. 10: model activation latency (0.6B-14B) by residency tier — the
profiled bandwidth model (sleeping / host / disk / remote vs QLM restart),
plus REAL measured warm-vs-cold activation on the tiny CPU model zoo."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import banner, save_result
from repro.core.predictor.cost_model import HardwareSpec
from repro.core.runtime.residency import (RETRACE_COST_S,
                                          HierarchicalResidency, ModelState)
from repro.sim.simulator import default_profiles

HW = HardwareSpec(name="a100-40g", peak_flops=312e12, hbm_bw=1555e9,
                  hbm_capacity=40e9, host_link_bw=25e9)


def main(fast: bool = False):
    banner("Fig. 10 — model activation latency by tier")
    profiles = default_profiles(HW)
    rows = []
    for name, prof in profiles.items():
        res = HierarchicalResidency({name: prof}, c_gpu=40e9, c_cpu=512e9,
                                    c_disk=2e12, hw=HW)
        t_remote = res.activation_latency(name)
        res.state[name] = ModelState.DISK
        t_disk = res.activation_latency(name)
        res.state[name] = ModelState.CPU
        t_cpu = res.activation_latency(name)
        res.state[name] = ModelState.SLEEPING
        t_sleep = res.activation_latency(name)
        rows.append({"model": name, "sleeping_s": round(t_sleep, 2),
                     "cpu_restart_s": round(t_cpu, 2),
                     "disk_s": round(t_disk, 2),
                     "remote_s": round(t_remote, 2)})
        print(f"{name:12s} sleeping={t_sleep:6.2f}s cpu+retrace={t_cpu:6.2f}s"
              f" disk={t_disk:6.2f}s remote={t_remote:7.2f}s")
        assert t_sleep < t_cpu < t_disk < t_remote

    # REAL measurement on CPU with a tiny model: warm context vs cold trace
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.node_runtime import NodeRuntime
    from repro.serving.engine import Request
    cfg = get_config("qwen3-8b").reduced()
    m = build_model(cfg)
    host = {"tiny": jax.tree.map(np.asarray, m.init(jax.random.PRNGKey(0)))}
    node = NodeRuntime(0, 0, {"tiny": m}, host, hbm_budget=1e9,
                       max_slots=2, s_max=48)
    t_cold = node.activate("tiny")
    node.submit("tiny", Request(req_id=0, tokens=[1, 2, 3], max_new=4))
    for _ in range(8):
        node.step()
    node.sleep("tiny")
    t_warm = node.activate("tiny")
    print(f"measured (tiny model, CPU): cold={t_cold*1e3:.0f}ms "
          f"warm-reactivate={t_warm*1e3:.0f}ms "
          f"({t_cold/max(t_warm,1e-9):.0f}x)")
    assert t_warm < t_cold
    save_result("fig10_activation", {"modeled": rows,
                                     "measured_cold_s": t_cold,
                                     "measured_warm_s": t_warm})
    return rows


if __name__ == "__main__":
    main()
