"""Live serving-plane benchmark: the sim's policy comparison on REAL engines.

Serves the same generated multi-agent trace through ``ClusterGateway`` under
EVERY policy in the unified registry (fcfs / least-loaded / edf /
oracle-srtf / maestro / maestro-np / baseline-lb / binpack / maestro-aff) on
an identical fleet (fresh engines per policy, shared model weights), and
reports live throughput, p95 latency, interactive queue delay and SLO
attainment — the prototype-experiment counterpart of Fig. 7 / Table II /
Table VIII, with one row per registered policy. The returned payload is
persisted by ``benchmarks.run`` as ``BENCH_gateway.json`` so the live-plane
perf trajectory is machine-trackable across PRs.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from benchmarks.common import banner, get_predictor, get_trace
from repro.core.sched.policies import registered_policies
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet

def _spec() -> ClusterSpec:
    # 4 real nodes over 3 clusters (two same-region, one remote)
    return ClusterSpec(nodes=(NodeSpec(0, max_slots=2),
                              NodeSpec(0, max_slots=2, hbm_budget=0.8e9),
                              NodeSpec(1, max_slots=2),
                              NodeSpec(2, max_slots=2)))


def main(n_jobs: int = 240, rate: float = 2.0, fast: bool = False,
         seed: int = 13, policies: Optional[Sequence[str]] = None,
         backend: str = "inproc") -> Dict:
    banner(f"gateway: live cross-cluster serving ({n_jobs} jobs, "
           f"{backend} nodes)")
    names = tuple(policies) if policies else registered_policies()
    pred = get_predictor(n_jobs=800 if fast else 1500, fast=fast)
    spec = _spec()
    # worker processes build their own zoos; only the in-process fleet
    # shares one host-tier parameter registry across policies
    zoo, host = (None, None) if backend == "process" \
        else build_zoo(spec.model_names)
    trace = get_trace(n_jobs, seed=seed, rate=rate)
    n_clusters = spec.rtt_s.shape[0]

    rows: List[Dict] = []
    for policy in names:
        fleet = build_fleet(spec, zoo=zoo, host=host, backend=backend)
        jobs = jobs_from_trace(trace, n_clusters=n_clusters, seed=seed)
        t0 = time.time()
        try:
            gw = ClusterGateway(fleet, spec.rtt_s, predictor=pred,
                                policy=policy,
                                cfg=GatewayConfig(node_backend=backend))
            m = gw.run(jobs)
        finally:
            # handles, not the gateway: covers constructor failures too
            close_fleet(fleet)
        wall = time.time() - t0
        assert m.finished_jobs > 0, f"{policy}: no jobs finished live"
        # every colocated engine drew its KV from one shared physical arena,
        # and the engines together advertised more virtual KV than was ever
        # physically mapped (§III.C spatial multiplexing, live)
        assert m.kv_overcommit_ratio > 1.0, \
            f"{policy}: arena not overcommitted ({m.kv_overcommit_ratio})"
        if backend == "process":
            # workers really spawned and exercised: every node did engine
            # work in its own process (ipc_calls alone would be vacuous —
            # metrics() itself costs one kv_stats round trip per node)
            assert m.ipc_calls > 0 and all(
                w["worker_step_wall_s"] > 0
                for w in m.worker_stats.values()), \
                f"{policy}: worker counters empty ({m.worker_stats})"
        row = m.row()
        row["wall_s"] = round(wall, 1)
        row["virtual_s"] = round(gw.now, 2)
        rows.append(row)
        ipc = (f"ipc={m.ipc_calls} ({m.ipc_wall_s:.1f}s) "
               if backend == "process" else "")
        print(f"[gateway] {policy:>13}: slo={m.slo_attainment:.2f} "
              f"int_qd={m.interactive_queue_delay_s:.2f}s "
              f"p95={m.p95_latency_s:.2f}s "
              f"thr={m.throughput_stages_per_s:.2f}st/s "
              f"cold={m.cold_starts} preempt={m.preemptions} "
              f"fin={m.finished_jobs}/{n_jobs} "
              f"kv_oc={m.kv_overcommit_ratio:.1f}x "
              f"pages={m.arena_peak_pages} {ipc}({wall:.0f}s wall)")

    by = {r["policy"]: r for r in rows}
    payload = {
        "n_jobs": n_jobs,
        "n_stages": sum(len(j.stages) for j in trace),
        "rate_jobs_per_s": rate,
        "nodes": len(spec.nodes),
        "clusters": spec.n_clusters,
        "node_backend": backend,
        "zoo": list(spec.model_names),
        "policies": list(names),
        "rows": rows,
    }
    if "fcfs" in by and "maestro" in by:
        gain = (by["fcfs"]["interactive_queue_delay_s"]
                - by["maestro"]["interactive_queue_delay_s"])
        print(f"[gateway] maestro vs fcfs interactive queue delay: "
              f"{'-' if gain >= 0 else '+'}{abs(gain):.2f}s "
              f"({'better' if gain > 0 else 'WORSE — investigate'})")
        payload["maestro_minus_fcfs_interactive_qd_s"] = -gain
    return payload


if __name__ == "__main__":
    main(n_jobs=24, fast=True)
