"""Live serving-plane benchmark: the sim's policy comparison on REAL engines.

Serves the same generated multi-agent trace through ``ClusterGateway`` under
EVERY policy in the unified registry (fcfs / least-loaded / edf /
oracle-srtf / maestro / maestro-np / baseline-lb / binpack / maestro-aff) on
an identical fleet (fresh engines per policy, shared model weights), and
reports live throughput, p95 latency, interactive queue delay and SLO
attainment — the prototype-experiment counterpart of Fig. 7 / Table II /
Table VIII, with one row per registered policy. The returned payload is
persisted by ``benchmarks.run`` as ``BENCH_gateway.json`` so the live-plane
perf trajectory is machine-trackable across PRs.

``wall_main`` (``--clock wall``) is the clock-plane counterpart: the same
trace served under the WALL clock on both node backends, measuring real
elapsed makespan and per-node overlap — the row that demonstrates worker
processes genuinely overlap engine compute in measured time. Persisted as
``BENCH_gateway_wall.json`` (machine-dependent; never clobbers the virtual
baselines — see docs/BENCHMARKS.md).

``socket_main`` (the ``gateway_socket`` bench) exercises the framed-TCP
transport + membership plane end-to-end: virtual-clock parity of the socket
fleet against the pipe fleet, a wall-clock leg with transport-overhead
columns, and a fault-injection leg that SIGKILLs a worker mid-run and
asserts recovery. Persisted as ``BENCH_gateway_socket.json``.
"""
from __future__ import annotations

import os
import signal
import time
from typing import Dict, List, Optional, Sequence

from benchmarks.common import banner, get_predictor, get_trace
from repro.core.sched.policies import POLICIES, registered_policies
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   build_zoo, jobs_from_trace)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet

def _spec() -> ClusterSpec:
    # 4 real nodes over 3 clusters (two same-region, one remote)
    return ClusterSpec(nodes=(NodeSpec(0, max_slots=2),
                              NodeSpec(0, max_slots=2, hbm_budget=0.8e9),
                              NodeSpec(1, max_slots=2),
                              NodeSpec(2, max_slots=2)))


def main(n_jobs: int = 240, rate: float = 2.0, fast: bool = False,
         seed: int = 13, policies: Optional[Sequence[str]] = None,
         backend: str = "inproc") -> Dict:
    banner(f"gateway: live cross-cluster serving ({n_jobs} jobs, "
           f"{backend} nodes)")
    names = tuple(policies) if policies else registered_policies()
    pred = get_predictor(n_jobs=800 if fast else 1500, fast=fast)
    spec = _spec()
    # worker processes build their own zoos; only the in-process fleet
    # shares one host-tier parameter registry across policies
    zoo, host = (None, None) if backend != "inproc" \
        else build_zoo(spec.model_names)
    trace = get_trace(n_jobs, seed=seed, rate=rate)
    n_clusters = spec.rtt_s.shape[0]

    rows: List[Dict] = []
    for policy in names:
        fleet = build_fleet(spec, zoo=zoo, host=host, backend=backend)
        jobs = jobs_from_trace(trace, n_clusters=n_clusters, seed=seed)
        t0 = time.time()
        try:
            gw = ClusterGateway(fleet, spec.rtt_s, predictor=pred,
                                policy=policy,
                                cfg=GatewayConfig(node_backend=backend))
            m = gw.run(jobs)
        finally:
            # handles, not the gateway: covers constructor failures too
            close_fleet(fleet)
        wall = time.time() - t0
        assert m.finished_jobs > 0, f"{policy}: no jobs finished live"
        # every colocated engine drew its KV from one shared physical arena,
        # and the engines together advertised more virtual KV than was ever
        # physically mapped (§III.C spatial multiplexing, live)
        assert m.kv_overcommit_ratio > 1.0, \
            f"{policy}: arena not overcommitted ({m.kv_overcommit_ratio})"
        if backend in ("process", "socket"):
            # workers really spawned and exercised: every node did engine
            # work in its own process (ipc_calls alone would be vacuous —
            # metrics() itself costs one kv_stats round trip per node)
            assert m.ipc_calls > 0 and all(
                w["worker_step_wall_s"] > 0
                for w in m.worker_stats.values()), \
                f"{policy}: worker counters empty ({m.worker_stats})"
        if backend == "socket":
            # real bytes crossed the framed TCP transport
            assert m.rpc_bytes_sent > 0 and m.rpc_bytes_recv > 0, \
                f"{policy}: socket transport counters empty"
        row = m.row()
        row["wall_s"] = round(wall, 1)
        row["virtual_s"] = round(gw.now, 2)
        rows.append(row)
        ipc = (f"ipc={m.ipc_calls} ({m.ipc_wall_s:.1f}s) "
               if backend != "inproc" else "")
        print(f"[gateway] {policy:>13}: slo={m.slo_attainment:.2f} "
              f"int_qd={m.interactive_queue_delay_s:.2f}s "
              f"p95={m.p95_latency_s:.2f}s "
              f"thr={m.throughput_stages_per_s:.2f}st/s "
              f"cold={m.cold_starts} preempt={m.preemptions} "
              f"fin={m.finished_jobs}/{n_jobs} "
              f"kv_oc={m.kv_overcommit_ratio:.1f}x "
              f"pages={m.arena_peak_pages} {ipc}({wall:.0f}s wall)")

    by = {r["policy"]: r for r in rows}
    payload = {
        "n_jobs": n_jobs,
        "n_stages": sum(len(j.stages) for j in trace),
        "rate_jobs_per_s": rate,
        "nodes": len(spec.nodes),
        "clusters": spec.n_clusters,
        "node_backend": backend,
        "zoo": list(spec.model_names),
        "policies": list(names),
        "rows": rows,
    }
    if "fcfs" in by and "maestro" in by:
        gain = (by["fcfs"]["interactive_queue_delay_s"]
                - by["maestro"]["interactive_queue_delay_s"])
        print(f"[gateway] maestro vs fcfs interactive queue delay: "
              f"{'-' if gain >= 0 else '+'}{abs(gain):.2f}s "
              f"({'better' if gain > 0 else 'WORSE — investigate'})")
        payload["maestro_minus_fcfs_interactive_qd_s"] = -gain
    return payload


def _busy_probe(q) -> None:
    t0 = time.time()
    n = 0
    while time.time() - t0 < 0.4:
        for _ in range(10_000):
            n += 1
    q.put(n)


def host_parallel_scaling() -> float:
    """How much CPU-bound throughput this host gains from a second
    process: total iterations of two concurrent busy loops over one.
    ~2.0 on a real 2+-core machine; ~1.3 on a hyperthread-sibling or
    oversubscribed 2-vCPU container. The wall benchmark records this and
    only ASSERTS the process-fleet speedup where the host can physically
    express cross-process overlap — on a ~1.3x box the engine compute is
    hardware-serialized no matter how well the fleet overlaps, and the
    overlap_factor column is the meaningful evidence instead."""
    import multiprocessing as mp
    ctx = mp.get_context("spawn")

    def run(n_procs: int) -> int:
        q = ctx.Queue()
        ps = [ctx.Process(target=_busy_probe, args=(q,))
              for _ in range(n_procs)]
        for p in ps:
            p.start()
        total = sum(q.get() for _ in ps)
        for p in ps:
            p.join()
        return total

    single = run(1)
    return run(2) / max(single, 1)


#: two-process scaling below which a host cannot express cross-process
#: compute overlap (hyperthread siblings / CPU-quota containers)
_SCALING_FLOOR = 1.5


def _wall_spec() -> ClusterSpec:
    # 3 nodes over 3 clusters, batch-8 engines: wide enough that one
    # engine iteration carries real compute (per-step overhead amortizes
    # over the batch), roomy enough HBM that deep in-flight pipelining
    # never triggers Alg. 2 churn — the regime where cross-process overlap
    # is measurable even on small CI-class hosts
    return ClusterSpec(nodes=(NodeSpec(0, max_slots=8, hbm_budget=2e9),
                              NodeSpec(1, max_slots=8, hbm_budget=2e9),
                              NodeSpec(2, max_slots=8, hbm_budget=2e9)))


def wall_main(n_jobs: int = 64, rate: float = 16.0, seed: int = 7,
              policies: Optional[Sequence[str]] = None,
              max_run_s: float = 900.0, gen_cap: int = 48,
              repeats: int = 2, assert_speedup: bool = True) -> Dict:
    """Wall-clock gateway sweep: the SAME trace served under real time on
    the in-process fleet (engine steps serialized in the gateway process)
    and the worker-process fleet (free-running children), on the ≥3-node
    cross-cluster spec. The headline number is ``process_speedup_x`` —
    in-process wall makespan over process wall makespan; > 1 means the
    worker fleet's engine compute genuinely overlapped in measured time.

    Both fleets are WARMED before the measured window (``gw.warmup()``), so
    makespan compares steady-state serving, not per-process JIT compile.
    Each (policy, backend) cell runs ``repeats`` times INTERLEAVED and the
    per-backend makespan is the best-of (min) — small hosts have easily
    ±15% run-to-run noise, and interleaving keeps slow phases of the box
    from landing entirely on one backend.

    ``assert_speedup=False`` (CI smoke) asserts only completion, never
    latency — wall timings are machine-dependent and must not flake CI."""
    banner(f"gateway-wall: real-time serving ({n_jobs} jobs, "
           f"inproc vs process fleets, best of {repeats})")
    scaling = host_parallel_scaling()
    print(f"[gateway-wall] host 2-process scaling: {scaling:.2f}x "
          f"({'full' if scaling >= _SCALING_FLOOR else 'constrained'} "
          f"host; speedup asserted only on full hosts)")
    names = tuple(policies) if policies else ("least-loaded",)
    pred = (get_predictor(n_jobs=800, fast=True)
            if any(POLICIES[n].needs_predictor for n in names) else None)
    spec = _wall_spec()
    trace = get_trace(n_jobs, seed=seed, rate=rate)
    n_clusters = spec.rtt_s.shape[0]

    rows: List[Dict] = []
    speedups: Dict[str, float] = {}
    for policy in names:
        span: Dict[str, float] = {}
        for rep in range(max(1, repeats)):
            for backend in ("inproc", "process"):
                fleet = build_fleet(spec, backend=backend)
                jobs = jobs_from_trace(trace, n_clusters=n_clusters,
                                       seed=seed, prompt_cap=8,
                                       gen_cap=gen_cap)
                t0 = time.time()
                try:
                    gw = ClusterGateway(
                        fleet, spec.rtt_s, predictor=pred, policy=policy,
                        cfg=GatewayConfig(clock="wall",
                                          node_backend=backend,
                                          max_inflight_per_node=12,
                                          max_run_s=max_run_s))
                    gw.warmup()
                    m = gw.run(jobs)
                finally:
                    close_fleet(fleet)
                wall = time.time() - t0
                # completion, not latency: wall rows may never flake CI
                assert m.finished_jobs > 0, \
                    f"{policy}/{backend}: no jobs finished (wall clock)"
                assert m.clock == "wall" and m.wall_makespan_s > 0
                span[backend] = min(span.get(backend, float("inf")),
                                    m.makespan_s)
                row = m.row()
                row["wall_s"] = round(wall, 1)
                row["repeat"] = rep
                rows.append(row)
                print(f"[gateway-wall] {policy:>13}/{backend:<7} r{rep}: "
                      f"makespan={m.makespan_s:.1f}s "
                      f"overlap={m.overlap_factor:.2f} "
                      f"int_qd={m.interactive_queue_delay_s:.2f}s "
                      f"fin={m.finished_jobs}/{n_jobs} "
                      f"outcome={m.run_outcome} ({wall:.0f}s wall)")
        speedups[policy] = span["inproc"] / max(span["process"], 1e-9)
        print(f"[gateway-wall] {policy}: process fleet speedup "
              f"{speedups[policy]:.2f}x (best inproc {span['inproc']:.1f}s "
              f"vs best process {span['process']:.1f}s)")
        if assert_speedup and scaling >= _SCALING_FLOOR:
            # the acceptance bar for the clock plane: on a >=3-node fleet
            # the free-running worker fleet beats cooperative stepping in
            # real time. Only asserted on sized runs (never CI smoke) and
            # only where the host can express cross-process overlap at
            # all — on a constrained (~1.3x-scaling) container the engine
            # compute is hardware-serialized, makespans tie by physics,
            # and the process rows' overlap_factor > 1 is the evidence
            # that the fleet genuinely overlapped in measured time.
            assert speedups[policy] > 1.0, \
                f"{policy}: process wall makespan did not beat inproc " \
                f"({span})"
        elif assert_speedup:
            print(f"[gateway-wall] {policy}: speedup assertion skipped "
                  f"(host scaling {scaling:.2f}x < {_SCALING_FLOOR}x — "
                  f"compute is hardware-serialized here; see "
                  f"overlap_factor for the concurrency evidence)")
    return {
        "clock": "wall",
        "n_jobs": n_jobs,
        "n_stages": sum(len(j.stages) for j in trace),
        "rate_jobs_per_s": rate,
        "gen_cap": gen_cap,
        "nodes": len(spec.nodes),
        "clusters": spec.n_clusters,
        "max_slots": spec.nodes[0].max_slots,
        "max_run_s": max_run_s,
        "warmup": True,
        "repeats": repeats,
        "host_parallel_scaling_x": round(scaling, 2),
        "policies": list(names),
        "process_speedup_x": speedups,
        "rows": rows,
    }


# GatewayMetrics fields that legitimately differ between node backends on
# the virtual clock (mirrors tests/test_worker.py BACKEND_ONLY, plus the
# bench's own wall/virtual timing columns)
_SOCKET_BACKEND_ONLY = {
    "node_backend", "ipc_calls", "ipc_wall_s", "worker_step_wall_s",
    "worker_stats", "rpc_bytes_sent", "rpc_bytes_recv", "wall_s",
    "virtual_s", "rpc_wall_s", "leg",
}


def _socket_spec() -> ClusterSpec:
    # 2 nodes over 2 clusters: the smallest fleet where routing, RTT and
    # fault evacuation are all non-trivial, cheap enough that the socket
    # bench's five fleet boots fit the CI smoke budget
    import numpy as np
    return ClusterSpec(nodes=(NodeSpec(0, max_slots=2),
                              NodeSpec(1, max_slots=2)),
                       rtt_s=np.array([[0.001, 0.04], [0.04, 0.001]]))


def socket_main(n_jobs: int = 24, rate: float = 2.0, seed: int = 13,
                fault_jobs: int = 6, policy: str = "fcfs",
                max_run_s: float = 600.0) -> Dict:
    """Socket-transport gateway benchmark, three legs on one trace:

    1. **virtual parity** — the same trace under the deterministic virtual
       clock on the ``process`` (pipe) and ``socket`` (framed TCP) fleets;
       asserts bit-identical completion sets and metrics (modulo transport
       counters), the tentpole's parity contract.
    2. **wall** — the socket fleet under the wall clock, reporting the
       transport overhead columns (``rpc_wall_s``, bytes on the wire,
       heartbeat misses) next to the PR 5 wall columns.
    3. **fault** — a wall-clock run that SIGKILLs one worker mid-run and
       asserts the membership plane recovers: stages requeue, the run
       completes on the survivor, the death lands in telemetry.

    Persisted by ``benchmarks.run`` as ``BENCH_gateway_socket.json``
    (machine-dependent wall/fault legs; the parity leg is the
    deterministic part)."""
    banner(f"gateway-socket: framed-TCP fleet ({n_jobs} jobs parity, "
           f"{fault_jobs} jobs fault, policy={policy})")
    spec = _socket_spec()
    n_clusters = spec.rtt_s.shape[0]
    trace = get_trace(n_jobs, seed=seed, rate=rate)
    rows: List[Dict] = []

    def _leg(backend: str, clock: str, leg: str, jobs_trace,
             kill_one: bool = False, gen_cap: int = 16):
        fleet = build_fleet(spec, backend=backend)
        jobs = jobs_from_trace(jobs_trace, n_clusters=n_clusters, seed=seed,
                               gen_cap=gen_cap)
        victim = fleet[0]
        t0 = time.time()
        try:
            gw = ClusterGateway(
                fleet, spec.rtt_s, policy=policy,
                cfg=GatewayConfig(node_backend=backend, clock=clock,
                                  heartbeat_s=0.05 if kill_one else 0.25,
                                  max_run_s=max_run_s))
            if clock == "wall":
                gw.warmup()
            if not kill_one:
                m = gw.run(jobs)
            else:
                gw.submit_jobs(jobs)
                gw.clock.restart()
                gw.clock.set_deadline(max_run_s)
                killed = False
                while gw._unfinished() and not gw.clock.expired():
                    gw.step()
                    if not killed and any(
                            r.submitted and r.node_id == victim.node_id
                            for r in gw.inflight.values()):
                        os.kill(victim.proc.pid, signal.SIGKILL)
                        killed = True
                assert killed, "fault leg: victim never got submitted work"
                m = gw.metrics()
            events = {sid: (e.node_id, e.out_len, e.finish_t, e.dispatch_t)
                      for sid, e in gw.telemetry.events.items()
                      if e.finish_t > 0}
        finally:
            close_fleet(fleet)
        row = m.row()
        row["leg"] = leg
        row["wall_s"] = round(time.time() - t0, 1)
        row["rpc_wall_s"] = m.ipc_wall_s       # transport overhead column
        rows.append(row)
        print(f"[gateway-socket] {leg:>15}: fin={m.finished_jobs} jobs/"
              f"{m.finished_stages} stages outcome={m.run_outcome} "
              f"deaths={m.node_deaths} requeued={m.requeued_stages} "
              f"rpc={m.ipc_calls} ({m.ipc_wall_s:.2f}s, "
              f"{m.rpc_bytes_sent + m.rpc_bytes_recv} B) "
              f"hb_miss={m.heartbeat_misses} ({row['wall_s']:.0f}s wall)")
        return m, events, row

    # leg 1: virtual-clock parity, process (pipe) vs socket (framed TCP)
    m_p, ev_p, row_p = _leg("process", "virtual", "virtual_process", trace)
    m_s, ev_s, row_s = _leg("socket", "virtual", "virtual_socket", trace)
    assert ev_p == ev_s, "socket completion set diverged from process"
    mismatched = [k for k in row_p
                  if k not in _SOCKET_BACKEND_ONLY and row_p[k] != row_s[k]]
    assert not mismatched, f"socket parity broke on fields: {mismatched}"
    assert m_s.rpc_bytes_sent > 0 and m_s.rpc_bytes_recv > 0
    n_compared = len([k for k in row_p if k not in _SOCKET_BACKEND_ONLY])
    print(f"[gateway-socket] parity: {len(ev_p)} completions and "
          f"{n_compared} metric fields identical across transports")

    # leg 2: wall clock over TCP — the transport-overhead row
    m_w, _, _ = _leg("socket", "wall", "wall_socket", trace)
    assert m_w.finished_jobs > 0 and m_w.clock == "wall"

    # leg 3: wall clock + SIGKILL one worker mid-run
    fault_trace = get_trace(fault_jobs, seed=3, rate=4.0)
    m_f, ev_f, _ = _leg("socket", "wall", "fault_socket", fault_trace,
                        kill_one=True, gen_cap=12)
    total = sum(len(j.stages) for j in fault_trace)
    assert m_f.run_outcome == "completed", \
        f"fault leg did not complete: {m_f.run_outcome}"
    assert m_f.node_deaths == 1 and m_f.requeued_stages >= 1
    assert m_f.finished_stages == total and len(ev_f) == total

    return {
        "backend": "socket",
        "clock": "virtual+wall",
        "n_jobs": n_jobs,
        "fault_jobs": fault_jobs,
        "n_stages": sum(len(j.stages) for j in trace),
        "rate_jobs_per_s": rate,
        "nodes": len(spec.nodes),
        "clusters": spec.n_clusters,
        "policy": policy,
        "zoo": list(spec.model_names),
        "max_run_s": max_run_s,
        "parity_fields_identical": n_compared,
        "parity_completions": len(ev_p),
        "fault_requeued_stages": m_f.requeued_stages,
        "fault_heartbeat_misses": m_f.heartbeat_misses,
        "rows": rows,
    }


if __name__ == "__main__":
    main(n_jobs=24, fast=True)
