"""Tail-metric scenario suite: heavy-tail traffic + deterministic faults.

Sweeps scheduling policies over the named ``TAIL_SCENARIOS`` workload
families (diurnal sinusoid, Markov-modulated bursty overload, heavy-tailed
Zipf demand across the FULL 10-config zoo — vision, MoE, SSM and whisper
included) on the live gateway under the deterministic virtual clock, and
reports the tail columns the paper's contention claims live in: p99/p99.9
end-to-end latency and queue delay, SLO attainment under overload, and
per-model-family utilization. A fault leg replays one scenario with a
scripted :class:`~repro.serving.faultplan.FaultPlan` — kill a node, degrade
a cross-cluster link, restore it — and asserts the run completes on the
survivors with every in-flight stage finished exactly once, reporting
recovery-time-after-fault.

Persisted by ``benchmarks.run`` as ``BENCH_tail_scenarios.json``; the
``--clock wall`` variant (``BENCH_tail_scenarios_wall.json``) runs the
fault leg on a real socket worker fleet — an actual SIGKILL mid-run plus a
replacement node registered through the plan — so recovery is exercised
end-to-end through the transport + membership plane, not just the
in-process death path.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from benchmarks.common import banner, get_predictor
from repro.configs import get_config, list_configs
from repro.core.sched.policies import POLICIES
from repro.data.tracegen import TAIL_SCENARIOS, scenario_workload
from repro.serving.cluster import (ClusterSpec, NodeSpec, build_fleet,
                                   build_zoo, jobs_from_trace, worker_specs)
from repro.serving.faultplan import (DegradeLink, FaultPlan, KillWorker,
                                     RegisterNode, RestoreLink)
from repro.serving.gateway import ClusterGateway, GatewayConfig
from repro.serving.worker import close_fleet

FULL_ZOO = tuple(sorted(list_configs()))          # all ten model families
FAMILY = {name: get_config(name).family for name in FULL_ZOO}


def _spec() -> ClusterSpec:
    # 4 nodes over 3 clusters carrying the ENTIRE config zoo; chunked
    # prefill keeps the per-prompt-length retrace cost off the hot loop
    # for the attention families (SSM/encoder models keep monolithic
    # prefill by construction)
    node = dict(max_slots=4, hbm_budget=2.5e9, prefill_chunk_tokens=8)
    return ClusterSpec(nodes=(NodeSpec(0, **node), NodeSpec(0, **node),
                              NodeSpec(1, **node), NodeSpec(2, **node)),
                       model_names=FULL_ZOO)


def _family_util(m) -> Dict[str, Dict[str, int]]:
    """Fold per-model telemetry into per-family served stages/tokens."""
    stages: Dict[str, int] = {}
    tokens: Dict[str, int] = {}
    for name, n in m.stages_by_model.items():
        fam = FAMILY.get(name, "other")
        stages[fam] = stages.get(fam, 0) + n
        tokens[fam] = tokens.get(fam, 0) + m.tokens_by_model.get(name, 0)
    return {"stages": stages, "tokens": tokens}


def _run(spec, trace, policy, pred, backend="inproc", clock="virtual",
         zoo=None, host=None, fault_plan=None, seed=0, gen_cap=6,
         max_run_s=None, heartbeat_s=0.25, suspect_after_s=1.0,
         dead_after_s=5.0) -> Dict:
    fleet = build_fleet(spec, zoo=zoo, host=host, backend=backend)
    jobs = jobs_from_trace(trace, n_clusters=spec.rtt_s.shape[0], seed=seed,
                           prompt_cap=8, gen_cap=gen_cap)
    t0 = time.time()
    try:
        gw = ClusterGateway(fleet, spec.rtt_s, predictor=pred,
                            policy=policy,
                            cfg=GatewayConfig(node_backend=backend,
                                              clock=clock,
                                              heartbeat_s=heartbeat_s,
                                              suspect_after_s=suspect_after_s,
                                              dead_after_s=dead_after_s,
                                              max_run_s=max_run_s))
        if clock == "wall":
            gw.warmup()
        m = gw.run(jobs, fault_plan=fault_plan)
        finished_events = sum(1 for e in gw.telemetry.events.values()
                              if e.finish_t > 0)
    finally:
        close_fleet(fleet)
    total = sum(len(j.stages) for j in trace)
    row = m.row()
    row["wall_s"] = round(time.time() - t0, 1)
    row["total_stages"] = total
    row["finished_events"] = finished_events
    row["family_utilization"] = _family_util(m)
    if fault_plan is not None:
        row["fault_log"] = [[round(t, 3), what]
                            for t, what in fault_plan.fired]
    return row


def main(n_jobs: int = 1000, fault_jobs: int = 48, seed: int = 5,
         policies: Optional[Sequence[str]] = None,
         scenarios: Optional[Sequence[str]] = None,
         rate_scale: float = 1.0, clock: str = "virtual",
         max_run_s: float = 900.0) -> Dict:
    banner(f"tail-scenarios: heavy-tail traffic x faults ({n_jobs} jobs, "
           f"full {len(FULL_ZOO)}-model zoo, clock={clock})")
    scenarios = tuple(scenarios) if scenarios else tuple(TAIL_SCENARIOS)
    policies = tuple(policies) if policies else ("fcfs", "least-loaded",
                                                 "maestro")
    pred = (get_predictor(n_jobs=800, fast=True)
            if any(POLICIES[p].needs_predictor for p in policies) else None)
    spec = _spec()
    zoo, host = build_zoo(spec.model_names)
    rows: List[Dict] = []

    if clock == "wall":
        # wall mode is the e2e fault leg only: a REAL socket worker fleet,
        # a real SIGKILL scheduled on the clock plane, a link degradation,
        # and a replacement worker registered mid-run by the plan —
        # recovery through transport EOF / heartbeats, not a shortcut.
        # Wall rows assert completion + exactly-once, never latency. The
        # zoo is trimmed to small dense configs: this leg measures the
        # transport + membership plane, not model coverage (the virtual
        # fault leg keeps the full zoo), and each socket child pays its
        # own cold-compile per model it serves.
        wall_zoo = ("qwen3-8b", "starcoder2-15b")
        wall_spec = ClusterSpec(nodes=spec.nodes, rtt_s=spec.rtt_s,
                                model_names=wall_zoo)
        row = _fault_leg(wall_spec, fault_jobs, seed, rate_scale,
                         backend="socket", clock="wall",
                         max_run_s=max_run_s, rows=rows)
        return {
            "clock": "wall",
            "backend": "socket",
            "n_jobs": fault_jobs,
            "zoo": list(wall_zoo),
            "scenario": "heavy-tail-zoo",
            "recovery_time_s": row["recovery_time_s"],
            "rows": rows,
        }

    # ---- scenario x policy sweep (virtual clock, deterministic) ----
    for scenario in scenarios:
        trace = scenario_workload(scenario, n_jobs, seed=seed,
                                  rate_scale=rate_scale)
        for policy in policies:
            row = _run(spec, trace, policy, pred, zoo=zoo, host=host,
                       seed=seed)
            row["scenario"] = scenario
            rows.append(row)
            assert row["finished_jobs"] > 0, \
                f"{scenario}/{policy}: no jobs finished"
            assert row["finished_events"] == row["finished_stages"], \
                f"{scenario}/{policy}: duplicate stage completions"
            fams = set(row["family_utilization"]["stages"])
            print(f"[tail] {scenario:>16}/{policy:<12} "
                  f"slo={row['slo_attainment']:.2f} "
                  f"p99={row['p99_latency_s']:.1f}s "
                  f"p99.9={row['p999_latency_s']:.1f}s "
                  f"qd_p99={row['queue_delay_p99_s']:.1f}s "
                  f"fin={row['finished_jobs']}/{n_jobs} "
                  f"families={len(fams)} ({row['wall_s']:.0f}s wall)")
        # heavy-tail demand must actually reach the whole zoo: every model
        # family served at least one stage in every scenario
        served = set()
        for r in rows:
            if r["scenario"] == scenario:
                served |= set(r["family_utilization"]["stages"])
        assert served == set(FAMILY.values()), \
            f"{scenario}: families missing traffic: " \
            f"{set(FAMILY.values()) - served}"

    # ---- deterministic fault leg (virtual clock, in-process fleet) ----
    fault_row = _fault_leg(spec, fault_jobs, seed, rate_scale,
                           backend="inproc", clock="virtual",
                           zoo=zoo, host=host, rows=rows)

    return {
        "n_jobs": n_jobs,
        "fault_jobs": fault_jobs,
        "seed": seed,
        "rate_scale": rate_scale,
        "nodes": len(spec.nodes),
        "clusters": spec.n_clusters,
        "zoo": list(FULL_ZOO),
        "scenarios": list(scenarios),
        "policies": list(policies),
        "recovery_time_s": fault_row["recovery_time_s"],
        "rows": rows,
    }


def _fault_leg(spec: ClusterSpec, fault_jobs: int, seed: int,
               rate_scale: float, backend: str, clock: str,
               zoo=None, host=None, max_run_s: Optional[float] = None,
               rows: Optional[List[Dict]] = None) -> Dict:
    """One scripted-fault run on the heavy-tail-zoo scenario: node 0 dies
    a third of the way in, the cluster-0<->1 link degrades 25x shortly
    after and recovers later; on the socket backend a replacement worker
    also boots mid-run. Asserts completion on the survivors with every
    stage finished exactly once."""
    trace = scenario_workload("heavy-tail-zoo", fault_jobs, seed=seed,
                              rate_scale=rate_scale)
    span = max(j.arrival_s for j in trace)
    events = [KillWorker(at_s=span * 0.33, node_id=0),
              DegradeLink(at_s=span * 0.4, src_cluster=0, dst_cluster=1,
                          factor=25.0),
              RestoreLink(at_s=span * 0.8, src_cluster=0, dst_cluster=1)]
    if backend == "socket":
        # replacement worker: same zoo, joins cluster 0 under a fresh id
        # (booted by the plan when the event fires, like an autoscaler)
        grown = ClusterSpec(nodes=spec.nodes + (spec.nodes[0],),
                            rtt_s=spec.rtt_s,
                            model_names=spec.model_names)
        wspec = worker_specs(grown)[-1]

        def boot_replacement():
            from repro.serving.worker import spawn_fleet
            return spawn_fleet([wspec], backend="socket")[0]

        events.append(RegisterNode(at_s=span * 0.5,
                                   factory=boot_replacement))
    plan = FaultPlan(events)
    # wall: generous death threshold — socket children cold-compile each
    # model they serve, and a busy child can't answer pings mid-compile
    row = _run(spec, trace, "least-loaded", None, backend=backend,
               clock=clock, zoo=zoo, host=host, fault_plan=plan, seed=seed,
               max_run_s=max_run_s,
               heartbeat_s=0.05 if clock == "wall" else 0.25,
               suspect_after_s=5.0 if clock == "wall" else 1.0,
               dead_after_s=30.0 if clock == "wall" else 5.0)
    row["scenario"] = "heavy-tail-zoo+faults"
    if rows is not None:
        rows.append(row)
    total = row["total_stages"]
    assert row["run_outcome"] == "completed", \
        f"fault leg did not complete: {row['run_outcome']}"
    assert row["node_deaths"] == 1, \
        f"expected exactly one death, got {row['node_deaths']}"
    # exactly-once: every stage of the trace finished, each with a single
    # telemetry completion — evacuation requeued, never duplicated
    assert row["finished_stages"] == total \
        and row["finished_events"] == total, \
        f"exactly-once violated: {row['finished_stages']}/" \
        f"{row['finished_events']} of {total}"
    if row["requeued_stages"] > 0:
        assert row["recovery_time_s"] > 0.0
    fired = [what for _, what in plan.fired]
    assert any(w.startswith("kill node 0") for w in fired), fired
    if backend == "socket":
        assert any(w.startswith("register node") for w in fired), fired
    print(f"[tail] fault leg ({backend}/{clock}): "
          f"deaths={row['node_deaths']} requeued={row['requeued_stages']} "
          f"recovery={row['recovery_time_s']:.2f}s "
          f"fin={row['finished_stages']}/{total} stages exactly once "
          f"({row['wall_s']:.0f}s wall)")
    return row


if __name__ == "__main__":
    main(n_jobs=60, fault_jobs=24)
