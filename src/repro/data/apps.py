"""The 9 LLM-MAS application templates of Table I.

Each template encodes a common agent topology (serial tool-use loops,
supervisor-worker fan-out/fan-in, multi-step reasoning with refinement) as a
DAG of role-typed stage templates. Jobs instantiated from a template share
application logic but differ in inputs — matching §IV.A's trace construction.

Output-length ground truth is generated from role/tool/CoT-conditioned
distributions (Observation-1: tool stages emit short structured outputs;
CoT shifts outputs heavy-tailed), modulated by a latent prompt "complexity"
that is expressed in the prompt TEXT — so the semantic encoder has real
signal to recover (Table VII's ablation direction).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

# role ids
ROLES = ["planner", "solver", "critic", "tool_agent", "writer", "translator",
         "supervisor", "worker", "summarizer", "coder", "reviewer", "chat"]
ROLE_ID = {r: i for i, r in enumerate(ROLES)}

# the node-level model zoo (Table V's Qwen3 family, by id)
MODELS = ["qwen3-0.6b", "qwen3-1.7b", "qwen3-4b", "qwen3-8b", "qwen3-14b"]
MODEL_PARAMS_B = [0.6, 1.7, 4.0, 8.0, 14.0]


@dataclasses.dataclass(frozen=True)
class StageTemplate:
    role: str
    model_id: int
    tools_available: int = 0
    p_tool: float = 0.0          # prob. this stage actually makes a tool call
    cot: bool = False
    base_len: float = 180.0      # lognormal median of output tokens (non-tool)
    sigma: float = 0.6           # lognormal sigma (CoT adds +0.35)
    tool_len: float = 45.0       # median when the stage emits a tool call
    prompt_base: int = 300
    deps: Tuple[int, ...] = ()   # indices of prerequisite stages
    loop: float = 0.0            # prob. of repeating this stage (geometric)
    fanout: int = 1              # >1 => supervisor-worker parallel copies


@dataclasses.dataclass(frozen=True)
class AppTemplate:
    name: str
    interactive: bool
    weight: float                # job mix proportion (Table I #Jobs)
    stages: Tuple[StageTemplate, ...]
    slo_factor: float = 2.0      # deadline = slo_factor x isolated p50


def _st(role, model_id, **kw) -> StageTemplate:
    return StageTemplate(role=role, model_id=model_id, **kw)


APPS: List[AppTemplate] = [
    AppTemplate("meeting_booking", True, 8626 / 46769, (
        _st("planner", 1, base_len=120, prompt_base=200),
        _st("tool_agent", 0, tools_available=3, p_tool=0.85, base_len=150,
            tool_len=40, deps=(0,), loop=0.35),
        _st("chat", 1, base_len=90, prompt_base=350, deps=(1,)),
    )),
    AppTemplate("document_writing", False, 8319 / 46769, (
        _st("planner", 2, base_len=250, cot=True, prompt_base=400),
        _st("writer", 3, base_len=700, sigma=0.7, prompt_base=600, deps=(0,)),
        _st("critic", 2, base_len=220, cot=True, deps=(1,), loop=0.4),
        _st("writer", 3, base_len=500, prompt_base=900, deps=(2,)),
    )),
    AppTemplate("news_collection", False, 6616 / 46769, (
        _st("supervisor", 2, base_len=200, prompt_base=250),
        _st("worker", 0, tools_available=2, p_tool=0.7, base_len=180,
            tool_len=50, deps=(0,), fanout=4),
        _st("summarizer", 3, base_len=420, prompt_base=1500, deps=(1,)),
    )),
    AppTemplate("performance", False, 6548 / 46769, (
        _st("tool_agent", 1, tools_available=2, p_tool=0.8, base_len=160,
            tool_len=35, prompt_base=800),
        _st("solver", 3, base_len=450, cot=True, prompt_base=1000, deps=(0,)),
        _st("writer", 2, base_len=380, deps=(1,)),
    )),
    AppTemplate("qa_assistant", True, 5849 / 46769, (
        _st("solver", 4, base_len=380, cot=True, sigma=0.8, prompt_base=500,
            tools_available=2, p_tool=0.3, tool_len=60, loop=0.3),
        _st("critic", 1, base_len=150, deps=(0,)),
        _st("chat", 3, base_len=260, prompt_base=700, deps=(1,)),
    )),
    AppTemplate("text_translation", False, 5124 / 46769, (
        _st("planner", 0, base_len=80, prompt_base=150),
        _st("translator", 1, base_len=550, sigma=0.5, prompt_base=700,
            deps=(0,), fanout=3),
        _st("critic", 1, base_len=120, deps=(1,)),
    )),
    AppTemplate("food_assistant", True, 3334 / 46769, (
        _st("chat", 0, base_len=110, prompt_base=200),
        _st("tool_agent", 0, tools_available=4, p_tool=0.9, base_len=130,
            tool_len=35, deps=(0,), loop=0.45),
        _st("chat", 1, base_len=140, deps=(1,)),
    )),
    AppTemplate("travel_assistant", True, 1543 / 46769, (
        # the real multi-model workflow of Table IV: six invocations, 3 models
        _st("planner", 2, base_len=220, cot=True, prompt_base=300),
        _st("tool_agent", 0, tools_available=5, p_tool=0.9, base_len=140,
            tool_len=45, deps=(0,)),
        _st("solver", 2, base_len=300, deps=(1,)),
        _st("tool_agent", 0, tools_available=5, p_tool=0.85, base_len=140,
            tool_len=45, deps=(2,)),
        _st("writer", 4, base_len=420, prompt_base=900, deps=(3,)),
        _st("chat", 2, base_len=160, deps=(4,)),
    )),
    AppTemplate("code_refactoring", False, 810 / 46769, (
        _st("planner", 3, base_len=300, cot=True, prompt_base=2500),
        _st("coder", 4, base_len=900, sigma=0.8, cot=True, prompt_base=3000,
            deps=(0,), loop=0.5),
        _st("reviewer", 3, base_len=350, cot=True, prompt_base=3500,
            deps=(1,)),
    )),
]

APP_ID = {a.name: i for i, a in enumerate(APPS)}


def interactive_ratio() -> float:
    return sum(a.weight for a in APPS if a.interactive)
