"""Multi-agent workflow trace generation (§IV.A).

Instantiates jobs from the Table-I templates with Poisson arrivals, unrolls
loops / fan-outs into stage DAGs, and samples ground-truth prompt/output
lengths with learnable structure:

  L ~ tool-call?  LogNormal(ln tool_len, 0.35)
      otherwise   LogNormal(ln base_len * (1 + complexity), sigma + 0.35*cot)

``complexity`` is a latent in [0,1] EXPRESSED IN THE PROMPT TEXT via signal
vocabulary — recoverable only through the semantic encoder (drives the
Table-VII ablation). The batch ratio can be re-weighted to sweep Fig. 7's
x-axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.predictor.features import StageObservation
from repro.data.apps import APPS, APP_ID, MODELS, ROLE_ID, AppTemplate

_FILLER = ("the a of to and on for with into from about please could review "
           "data result answer item report note info step check list").split()
_COMPLEX_WORDS = ("thorough detailed comprehensive intricate elaborate "
                  "multifaceted exhaustive rigorous").split()
_SIMPLE_WORDS = "brief quick short simple concise minimal".split()
_TOPIC = ("travel menu booking flight code bug patch news market translation "
          "meeting schedule health recipe budget analysis").split()


@dataclasses.dataclass
class StageRecord:
    job_id: int
    stage_id: int
    deps: List[int]
    obs: StageObservation
    interactive: bool
    # ground truth (hidden from the scheduler until completion)
    true_len: int
    tool_call: bool
    # shared-prefix structure (team traces only): ordered (block_key,
    # n_tokens) pairs describing the prompt as a concatenation of named
    # blocks. Stages whose block sequences share a prefix share the SAME
    # leading prompt tokens when materialized (``jobs_from_trace`` derives
    # each block's token ids from its key alone), which is what the
    # cross-stage prefix cache exploits. None for classic traces.
    prompt_blocks: Optional[Tuple[Tuple[str, int], ...]] = None

    @property
    def model(self) -> str:
        return MODELS[self.obs.model_id]


@dataclasses.dataclass
class JobRecord:
    job_id: int
    app: str
    interactive: bool
    arrival_s: float
    stages: List[StageRecord]
    deadline_s: float = 0.0   # filled by the SLO profiler


def _prompt_text(rng, role: str, complexity: float, n_words: int) -> str:
    total = min(160, max(16, n_words // 8))
    # complexity expressed as a DENSITY of signal vocabulary (so the
    # window-mean-pooled embedding amplitude tracks it at any prompt length)
    n_sig = int(round(complexity * 0.35 * total))
    n_simple = int(round((1.0 - complexity) * 0.15 * total))
    words = list(rng.choice(_COMPLEX_WORDS, n_sig))
    words += list(rng.choice(_SIMPLE_WORDS, n_simple))
    words += list(rng.choice(_TOPIC, 3))
    words += list(rng.choice(_FILLER, max(4, total - len(words))))
    rng.shuffle(words)
    return " ".join(words)


def generate_trace(n_jobs: int, rate: float = 1.0,
                   batch_ratio: Optional[float] = None,
                   seed: int = 0) -> List[JobRecord]:
    """Poisson arrivals at `rate` jobs/s. batch_ratio rebalances the app mix
    (None keeps Table-I proportions)."""
    rng = np.random.default_rng(seed)
    weights = np.array([a.weight for a in APPS])
    if batch_ratio is not None:
        is_b = np.array([not a.interactive for a in APPS])
        w = weights.copy()
        w[is_b] *= batch_ratio / max(w[is_b].sum(), 1e-9)
        w[~is_b] *= (1 - batch_ratio) / max(w[~is_b].sum(), 1e-9)
        weights = w
    weights = weights / weights.sum()

    jobs: List[JobRecord] = []
    t = 0.0
    sid = 0
    for j in range(n_jobs):
        t += rng.exponential(1.0 / rate)
        app = APPS[rng.choice(len(APPS), p=weights)]
        stages: List[StageRecord] = []
        # unroll the template (loops + fanout) into a concrete DAG
        tmpl_to_last: Dict[int, List[int]] = {}  # template idx -> stage ids
        invocation = 0
        for ti, st in enumerate(app.stages):
            dep_ids: List[int] = []
            for d in st.deps:
                dep_ids += tmpl_to_last.get(d, [])
            copies = st.fanout if st.fanout > 1 else 1
            ids = []
            for c in range(copies):
                reps = 1
                while st.loop > 0 and rng.random() < st.loop and reps < 4:
                    reps += 1
                prev = list(dep_ids)
                for r in range(reps):
                    complexity = float(rng.random())
                    tool_call = bool(st.tools_available > 0
                                     and rng.random() < st.p_tool)
                    if tool_call:
                        L = rng.lognormal(np.log(st.tool_len), 0.25)
                    else:
                        # complexity (expressed in the prompt text) drives a
                        # ~6x dynamic range; residual lognormal noise is wider
                        # under CoT (heavy tail, Observation-1 / Fig. 1)
                        sig = 0.42 * st.sigma + (0.22 if st.cot else 0.0)
                        L = rng.lognormal(
                            np.log(st.base_len * (0.4 + 2.2 * complexity)), sig)
                    L = int(np.clip(L, 4, 8192))
                    P = int(np.clip(rng.lognormal(
                        np.log(st.prompt_base), 0.4), 16, 16384))
                    obs = StageObservation(
                        app=APP_ID[app.name], role=ROLE_ID[st.role],
                        position=ti / max(len(app.stages) - 1, 1),
                        invocation_idx=invocation,
                        tools_available=st.tools_available,
                        cot=st.cot, prompt_len=P, model_id=st.model_id,
                        text=_prompt_text(rng, st.role, complexity, P),
                        src_cluster=int(rng.integers(0, 3)))
                    rec = StageRecord(job_id=j, stage_id=sid, deps=prev,
                                      obs=obs, interactive=app.interactive,
                                      true_len=L, tool_call=tool_call)
                    stages.append(rec)
                    prev = [sid]
                    sid += 1
                    invocation += 1
                ids += prev
            tmpl_to_last[ti] = ids
        jobs.append(JobRecord(job_id=j, app=app.name,
                              interactive=app.interactive,
                              arrival_s=t, stages=stages))
    return jobs


# ---------------------------------------------------------------------------
# Multi-agent TEAM traces: workflows with explicit shared-prefix structure
# ---------------------------------------------------------------------------

# (shape name, app template name it reports as, ((role, deps), ...))
# Conversation-style topologies: every stage's prompt embeds its parent's
# full transcript (system prompt + every upstream turn) plus its own role
# header and turn — the LLM-MAS pattern that makes cross-stage KV reuse pay.
_TEAM_SHAPES: Tuple[Tuple[str, str, Tuple[Tuple[str, Tuple[int, ...]], ...]],
                    ...] = (
    ("pipeline", "document_writing",
     (("planner", ()), ("solver", (0,)), ("critic", (1,)),
      ("summarizer", (2,)))),
    ("fanout", "news_collection",
     (("supervisor", ()), ("worker", (0,)), ("worker", (0,)),
      ("worker", (0,)), ("summarizer", (1, 2, 3)))),
    ("debate", "qa_assistant",
     (("planner", ()), ("solver", (0,)), ("critic", (0,)),
      ("summarizer", (1, 2)))),
)


def generate_team_trace(n_jobs: int, rate: float = 2.0, seed: int = 0,
                        n_teams: int = 3, sys_tokens: int = 32,
                        role_tokens: int = 8, turn_tokens: int = 12
                        ) -> List[JobRecord]:
    """Agent-team workflows whose prompts carry explicit shared-prefix
    structure (``StageRecord.prompt_blocks``):

    - every job of team ``t`` opens with the same ``team{t}:sys`` system
      block, so cross-JOB reuse exists within a team;
    - each stage's prompt is its parent's block sequence plus a reply
      block (shared by siblings of the same parent — fan-out workers and
      debate branches diverge only at their role header), a role block and
      a unique turn block, so cross-STAGE reuse exists along every DAG edge.

    Block token ids are derived from the block key alone (see
    ``jobs_from_trace``), so equal keys materialize to identical tokens.
    ``model_id`` alternates over the attention models of the live zoo
    (1 + team % 2 -> qwen3-8b / starcoder2-15b under the default 3-model
    fleet); the SSM family keeps serving the classic trace mix."""
    rng = np.random.default_rng(seed)
    jobs: List[JobRecord] = []
    t = 0.0
    sid = 0
    for j in range(n_jobs):
        t += rng.exponential(1.0 / rate)
        team = j % n_teams
        _, app_name, shape = _TEAM_SHAPES[int(rng.integers(
            0, len(_TEAM_SHAPES)))]
        app = APPS[APP_ID[app_name]]
        stages: List[StageRecord] = []
        local_ids: List[int] = []
        for li, (role, deps) in enumerate(shape):
            dep_ids = [local_ids[d] for d in deps]
            if dep_ids:
                parent = stages[deps[0]]       # one stage per shape slot
                blocks = list(parent.prompt_blocks)
                blocks.append((f"reply:{j}:{parent.stage_id}", turn_tokens))
            else:
                blocks = [(f"team{team}:sys", sys_tokens)]
            blocks.append((f"role:{role}", role_tokens))
            blocks.append((f"turn:{j}:{sid}", turn_tokens))
            complexity = float(rng.random())
            L = int(np.clip(rng.lognormal(np.log(60.0), 0.5), 4, 512))
            n_prompt = sum(n for _, n in blocks)
            obs = StageObservation(
                app=APP_ID[app_name], role=ROLE_ID[role],
                position=li / max(len(shape) - 1, 1),
                invocation_idx=li, tools_available=0, cot=False,
                prompt_len=n_prompt * 32, model_id=1 + (team % 2),
                text=_prompt_text(rng, role, complexity, n_prompt * 32),
                src_cluster=team % 3)
            stages.append(StageRecord(
                job_id=j, stage_id=sid, deps=dep_ids, obs=obs,
                interactive=app.interactive, true_len=L, tool_call=False,
                prompt_blocks=tuple(blocks)))
            local_ids.append(sid)
            sid += 1
        jobs.append(JobRecord(job_id=j, app=app_name,
                              interactive=app.interactive,
                              arrival_s=t, stages=stages))
    return jobs


def flatten_stages(jobs: Sequence[JobRecord]) -> List[StageRecord]:
    return [s for j in jobs for s in j.stages]


def stratified_temporal_split(jobs: Sequence[JobRecord], test_frac: float = 0.2
                              ) -> Tuple[List[StageRecord], List[StageRecord]]:
    """§IV.A: within each (agent, tool-use, thinking-mode) group, the latest
    test_frac of records are the test set."""
    groups: Dict[Tuple, List[StageRecord]] = {}
    for s in flatten_stages(jobs):
        groups.setdefault(
            (s.obs.role, s.tool_call, s.obs.cot), []).append(s)
    train, test = [], []
    for g in groups.values():
        g = sorted(g, key=lambda s: s.stage_id)
        k = max(1, int(len(g) * test_frac))
        train += g[:-k]
        test += g[-k:]
    train.sort(key=lambda s: s.stage_id)
    test.sort(key=lambda s: s.stage_id)
    return train, test
