"""Multi-agent workflow trace generation (§IV.A).

Instantiates jobs from the Table-I templates with Poisson arrivals, unrolls
loops / fan-outs into stage DAGs, and samples ground-truth prompt/output
lengths with learnable structure:

  L ~ tool-call?  LogNormal(ln tool_len, 0.35)
      otherwise   LogNormal(ln base_len * (1 + complexity), sigma + 0.35*cot)

``complexity`` is a latent in [0,1] EXPRESSED IN THE PROMPT TEXT via signal
vocabulary — recoverable only through the semantic encoder (drives the
Table-VII ablation). The batch ratio can be re-weighted to sweep Fig. 7's
x-axis.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.predictor.features import StageObservation
from repro.data.apps import APPS, APP_ID, MODELS, ROLE_ID, AppTemplate

_FILLER = ("the a of to and on for with into from about please could review "
           "data result answer item report note info step check list").split()
_COMPLEX_WORDS = ("thorough detailed comprehensive intricate elaborate "
                  "multifaceted exhaustive rigorous").split()
_SIMPLE_WORDS = "brief quick short simple concise minimal".split()
_TOPIC = ("travel menu booking flight code bug patch news market translation "
          "meeting schedule health recipe budget analysis").split()


@dataclasses.dataclass
class StageRecord:
    job_id: int
    stage_id: int
    deps: List[int]
    obs: StageObservation
    interactive: bool
    # ground truth (hidden from the scheduler until completion)
    true_len: int
    tool_call: bool
    # shared-prefix structure (team traces only): ordered (block_key,
    # n_tokens) pairs describing the prompt as a concatenation of named
    # blocks. Stages whose block sequences share a prefix share the SAME
    # leading prompt tokens when materialized (``jobs_from_trace`` derives
    # each block's token ids from its key alone), which is what the
    # cross-stage prefix cache exploits. None for classic traces.
    prompt_blocks: Optional[Tuple[Tuple[str, int], ...]] = None

    @property
    def model(self) -> str:
        return MODELS[self.obs.model_id]


@dataclasses.dataclass
class JobRecord:
    job_id: int
    app: str
    interactive: bool
    arrival_s: float
    stages: List[StageRecord]
    deadline_s: float = 0.0   # filled by the SLO profiler


def _prompt_text(rng, role: str, complexity: float, n_words: int) -> str:
    total = min(160, max(16, n_words // 8))
    # complexity expressed as a DENSITY of signal vocabulary (so the
    # window-mean-pooled embedding amplitude tracks it at any prompt length)
    n_sig = int(round(complexity * 0.35 * total))
    n_simple = int(round((1.0 - complexity) * 0.15 * total))
    words = list(rng.choice(_COMPLEX_WORDS, n_sig))
    words += list(rng.choice(_SIMPLE_WORDS, n_simple))
    words += list(rng.choice(_TOPIC, 3))
    words += list(rng.choice(_FILLER, max(4, total - len(words))))
    rng.shuffle(words)
    return " ".join(words)


def generate_trace(n_jobs: int, rate: float = 1.0,
                   batch_ratio: Optional[float] = None,
                   seed: int = 0) -> List[JobRecord]:
    """Poisson arrivals at `rate` jobs/s. batch_ratio rebalances the app mix
    (None keeps Table-I proportions)."""
    rng = np.random.default_rng(seed)
    weights = np.array([a.weight for a in APPS])
    if batch_ratio is not None:
        is_b = np.array([not a.interactive for a in APPS])
        w = weights.copy()
        w[is_b] *= batch_ratio / max(w[is_b].sum(), 1e-9)
        w[~is_b] *= (1 - batch_ratio) / max(w[~is_b].sum(), 1e-9)
        weights = w
    weights = weights / weights.sum()

    jobs: List[JobRecord] = []
    t = 0.0
    sid = 0
    for j in range(n_jobs):
        t += rng.exponential(1.0 / rate)
        app = APPS[rng.choice(len(APPS), p=weights)]
        stages: List[StageRecord] = []
        # unroll the template (loops + fanout) into a concrete DAG
        tmpl_to_last: Dict[int, List[int]] = {}  # template idx -> stage ids
        invocation = 0
        for ti, st in enumerate(app.stages):
            dep_ids: List[int] = []
            for d in st.deps:
                dep_ids += tmpl_to_last.get(d, [])
            copies = st.fanout if st.fanout > 1 else 1
            ids = []
            for c in range(copies):
                reps = 1
                while st.loop > 0 and rng.random() < st.loop and reps < 4:
                    reps += 1
                prev = list(dep_ids)
                for r in range(reps):
                    complexity = float(rng.random())
                    tool_call = bool(st.tools_available > 0
                                     and rng.random() < st.p_tool)
                    if tool_call:
                        L = rng.lognormal(np.log(st.tool_len), 0.25)
                    else:
                        # complexity (expressed in the prompt text) drives a
                        # ~6x dynamic range; residual lognormal noise is wider
                        # under CoT (heavy tail, Observation-1 / Fig. 1)
                        sig = 0.42 * st.sigma + (0.22 if st.cot else 0.0)
                        L = rng.lognormal(
                            np.log(st.base_len * (0.4 + 2.2 * complexity)), sig)
                    L = int(np.clip(L, 4, 8192))
                    P = int(np.clip(rng.lognormal(
                        np.log(st.prompt_base), 0.4), 16, 16384))
                    obs = StageObservation(
                        app=APP_ID[app.name], role=ROLE_ID[st.role],
                        position=ti / max(len(app.stages) - 1, 1),
                        invocation_idx=invocation,
                        tools_available=st.tools_available,
                        cot=st.cot, prompt_len=P, model_id=st.model_id,
                        text=_prompt_text(rng, st.role, complexity, P),
                        src_cluster=int(rng.integers(0, 3)))
                    rec = StageRecord(job_id=j, stage_id=sid, deps=prev,
                                      obs=obs, interactive=app.interactive,
                                      true_len=L, tool_call=tool_call)
                    stages.append(rec)
                    prev = [sid]
                    sid += 1
                    invocation += 1
                ids += prev
            tmpl_to_last[ti] = ids
        jobs.append(JobRecord(job_id=j, app=app.name,
                              interactive=app.interactive,
                              arrival_s=t, stages=stages))
    return jobs


# ---------------------------------------------------------------------------
# Multi-agent TEAM traces: workflows with explicit shared-prefix structure
# ---------------------------------------------------------------------------

# (shape name, app template name it reports as, ((role, deps), ...))
# Conversation-style topologies: every stage's prompt embeds its parent's
# full transcript (system prompt + every upstream turn) plus its own role
# header and turn — the LLM-MAS pattern that makes cross-stage KV reuse pay.
_TEAM_SHAPES: Tuple[Tuple[str, str, Tuple[Tuple[str, Tuple[int, ...]], ...]],
                    ...] = (
    ("pipeline", "document_writing",
     (("planner", ()), ("solver", (0,)), ("critic", (1,)),
      ("summarizer", (2,)))),
    ("fanout", "news_collection",
     (("supervisor", ()), ("worker", (0,)), ("worker", (0,)),
      ("worker", (0,)), ("summarizer", (1, 2, 3)))),
    ("debate", "qa_assistant",
     (("planner", ()), ("solver", (0,)), ("critic", (0,)),
      ("summarizer", (1, 2)))),
)


def generate_team_trace(n_jobs: int, rate: float = 2.0, seed: int = 0,
                        n_teams: int = 3, sys_tokens: int = 32,
                        role_tokens: int = 8, turn_tokens: int = 12
                        ) -> List[JobRecord]:
    """Agent-team workflows whose prompts carry explicit shared-prefix
    structure (``StageRecord.prompt_blocks``):

    - every job of team ``t`` opens with the same ``team{t}:sys`` system
      block, so cross-JOB reuse exists within a team;
    - each stage's prompt is its parent's block sequence plus a reply
      block (shared by siblings of the same parent — fan-out workers and
      debate branches diverge only at their role header), a role block and
      a unique turn block, so cross-STAGE reuse exists along every DAG edge.

    Block token ids are derived from the block key alone (see
    ``jobs_from_trace``), so equal keys materialize to identical tokens.
    ``model_id`` alternates over the attention models of the live zoo
    (1 + team % 2 -> qwen3-8b / starcoder2-15b under the default 3-model
    fleet); the SSM family keeps serving the classic trace mix."""
    rng = np.random.default_rng(seed)
    jobs: List[JobRecord] = []
    t = 0.0
    sid = 0
    for j in range(n_jobs):
        t += rng.exponential(1.0 / rate)
        team = j % n_teams
        _, app_name, shape = _TEAM_SHAPES[int(rng.integers(
            0, len(_TEAM_SHAPES)))]
        app = APPS[APP_ID[app_name]]
        stages: List[StageRecord] = []
        local_ids: List[int] = []
        for li, (role, deps) in enumerate(shape):
            dep_ids = [local_ids[d] for d in deps]
            if dep_ids:
                parent = stages[deps[0]]       # one stage per shape slot
                blocks = list(parent.prompt_blocks)
                blocks.append((f"reply:{j}:{parent.stage_id}", turn_tokens))
            else:
                blocks = [(f"team{team}:sys", sys_tokens)]
            blocks.append((f"role:{role}", role_tokens))
            blocks.append((f"turn:{j}:{sid}", turn_tokens))
            complexity = float(rng.random())
            L = int(np.clip(rng.lognormal(np.log(60.0), 0.5), 4, 512))
            n_prompt = sum(n for _, n in blocks)
            obs = StageObservation(
                app=APP_ID[app_name], role=ROLE_ID[role],
                position=li / max(len(shape) - 1, 1),
                invocation_idx=li, tools_available=0, cot=False,
                prompt_len=n_prompt * 32, model_id=1 + (team % 2),
                text=_prompt_text(rng, role, complexity, n_prompt * 32),
                src_cluster=team % 3)
            stages.append(StageRecord(
                job_id=j, stage_id=sid, deps=dep_ids, obs=obs,
                interactive=app.interactive, true_len=L, tool_call=False,
                prompt_blocks=tuple(blocks)))
            local_ids.append(sid)
            sid += 1
        jobs.append(JobRecord(job_id=j, app=app_name,
                              interactive=app.interactive,
                              arrival_s=t, stages=stages))
    return jobs


def flatten_stages(jobs: Sequence[JobRecord]) -> List[StageRecord]:
    return [s for j in jobs for s in j.stages]


def stratified_temporal_split(jobs: Sequence[JobRecord], test_frac: float = 0.2
                              ) -> Tuple[List[StageRecord], List[StageRecord]]:
    """§IV.A: within each (agent, tool-use, thinking-mode) group, the latest
    test_frac of records are the test set."""
    groups: Dict[Tuple, List[StageRecord]] = {}
    for s in flatten_stages(jobs):
        groups.setdefault(
            (s.obs.role, s.tool_call, s.obs.cot), []).append(s)
    train, test = [], []
    for g in groups.values():
        g = sorted(g, key=lambda s: s.stage_id)
        k = max(1, int(len(g) * test_frac))
        train += g[:-k]
        test += g[-k:]
    train.sort(key=lambda s: s.stage_id)
    test.sort(key=lambda s: s.stage_id)
    return train, test


# ---------------------------------------------------------------------------
# Production-traffic plane: pluggable arrival processes, heavy-tailed
# stage->model demand across the full zoo, heavy-tailed lengths.
#
# Everything below is ADDITIVE: ``generate_trace`` / ``generate_team_trace``
# above are frozen (their byte-exact outputs for existing seeds are pinned by
# tests/test_tracegen.py), and ``generate_workload`` draws from its own
# seeded streams so new knobs can never perturb legacy traces.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """Homogeneous Poisson arrivals at ``rate`` jobs/s."""
    rate: float = 1.0

    def scaled(self, factor: float) -> "PoissonArrivals":
        return dataclasses.replace(self, rate=self.rate * factor)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        return np.cumsum(rng.exponential(1.0 / self.rate, n))


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals:
    """Non-homogeneous Poisson with a sinusoidal day/night rate profile,

        rate(t) = base + (peak - base) * 0.5 * (1 + sin(2*pi*t/period + phase))

    sampled exactly by thinning against ``peak_rate`` (Lewis & Shedler), so
    the draw count per arrival is itself seeded and reproducible."""
    base_rate: float = 0.5
    peak_rate: float = 4.0
    period_s: float = 120.0
    phase: float = -np.pi / 2  # start at the trough: traces open quiet

    def scaled(self, factor: float) -> "DiurnalArrivals":
        return dataclasses.replace(self, base_rate=self.base_rate * factor,
                                   peak_rate=self.peak_rate * factor)

    def rate_at(self, t: float) -> float:
        swing = 0.5 * (1.0 + np.sin(2.0 * np.pi * t / self.period_s
                                    + self.phase))
        return self.base_rate + (self.peak_rate - self.base_rate) * swing

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if not 0 < self.base_rate <= self.peak_rate:
            raise ValueError("need 0 < base_rate <= peak_rate")
        out = np.empty(n)
        t = 0.0
        for i in range(n):
            while True:
                t += rng.exponential(1.0 / self.peak_rate)
                if rng.random() * self.peak_rate <= self.rate_at(t):
                    break
            out[i] = t
        return out


@dataclasses.dataclass(frozen=True)
class MarkovModulatedArrivals:
    """Markov-modulated Poisson process: phases cycle round-robin with
    exponential dwell times; within phase ``k`` arrivals are Poisson at
    ``rates[k]``. The default is the classic 2-phase on/off burst model
    (long quiet spells punctured by short overload bursts). Restarting the
    exponential inter-arrival draw at each phase boundary is exact because
    the Poisson process is memoryless."""
    rates: Tuple[float, ...] = (0.5, 12.0)
    dwell_s: Tuple[float, ...] = (30.0, 8.0)
    start_phase: int = 0

    def scaled(self, factor: float) -> "MarkovModulatedArrivals":
        return dataclasses.replace(
            self, rates=tuple(r * factor for r in self.rates))

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return self.sample_with_phases(rng, n)[0]

    def sample_with_phases(self, rng: np.random.Generator, n: int
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """Arrival times plus the phase index each arrival landed in (the
        phase trace is what the burst-occupancy property tests check)."""
        if len(self.rates) != len(self.dwell_s) or not self.rates:
            raise ValueError("rates and dwell_s must be equal-length, >= 1")
        if min(self.rates) <= 0 or min(self.dwell_s) <= 0:
            raise ValueError("rates and dwell times must be > 0")
        times = np.empty(n)
        phases = np.empty(n, np.int64)
        t = 0.0
        phase = self.start_phase % len(self.rates)
        phase_end = rng.exponential(self.dwell_s[phase])
        i = 0
        while i < n:
            dt = rng.exponential(1.0 / self.rates[phase])
            if t + dt <= phase_end:
                t += dt
                times[i] = t
                phases[i] = phase
                i += 1
            else:
                t = phase_end
                phase = (phase + 1) % len(self.rates)
                phase_end = t + rng.exponential(self.dwell_s[phase])
        return times, phases


ARRIVALS: Dict[str, type] = {
    "poisson": PoissonArrivals,
    "diurnal": DiurnalArrivals,
    "mmpp": MarkovModulatedArrivals,
}


@dataclasses.dataclass(frozen=True)
class ZipfDemand:
    """Heavy-tailed stage->model demand: rank ``k`` of the zoo gets
    probability proportional to ``(k+1)**-alpha``. ``order`` maps rank to
    model id (identity by default), so the hottest model is configurable.
    With ``n_models=10`` every family of the config zoo — vision, MoE, SSM,
    whisper included — receives traffic (``model_name`` resolves ids modulo
    the fleet's profile list)."""
    alpha: float = 1.1
    n_models: int = 10
    order: Optional[Tuple[int, ...]] = None

    def probs(self) -> np.ndarray:
        w = (np.arange(self.n_models) + 1.0) ** -self.alpha
        return w / w.sum()

    def model_id(self, rng: np.random.Generator) -> int:
        k = int(rng.choice(self.n_models, p=self.probs()))
        return int(self.order[k]) if self.order is not None else k


@dataclasses.dataclass(frozen=True)
class UniformDemand:
    """Uniform stage->model demand over the zoo (ablation baseline)."""
    n_models: int = 10

    def model_id(self, rng: np.random.Generator) -> int:
        return int(rng.integers(0, self.n_models))


DEMANDS: Dict[str, type] = {"zipf": ZipfDemand, "uniform": UniformDemand}


@dataclasses.dataclass(frozen=True)
class ParetoLengths:
    """Heavy-tailed prompt and output lengths: Lomax (Pareto type II),
    ``L = scale * (1 + Pareto(alpha))``, clipped to the engine bounds.
    alpha < 2 gives the infinite-variance decode tail that makes p99.9
    diverge from the mean (the regime Maestro's tail claims live in)."""
    out_scale: float = 90.0
    out_alpha: float = 1.5
    prompt_scale: float = 220.0
    prompt_alpha: float = 1.8
    out_cap: int = 8192
    prompt_cap: int = 16384

    def output_len(self, rng: np.random.Generator) -> int:
        L = self.out_scale * (1.0 + rng.pareto(self.out_alpha))
        return int(np.clip(L, 4, self.out_cap))

    def prompt_len(self, rng: np.random.Generator) -> int:
        P = self.prompt_scale * (1.0 + rng.pareto(self.prompt_alpha))
        return int(np.clip(P, 16, self.prompt_cap))


LENGTHS: Dict[str, type] = {"pareto": ParetoLengths}


def _make(registry: Dict[str, type], spec: Any, kind: str) -> Any:
    """Resolve a (name, kwargs) / name / instance spec against a registry."""
    if spec is None or not isinstance(spec, (str, tuple, list)):
        return spec  # already an instance (or None)
    if isinstance(spec, str):
        name, kwargs = spec, {}
    else:
        name, kwargs = spec[0], dict(spec[1]) if len(spec) > 1 else {}
    if name not in registry:
        raise KeyError(f"unknown {kind} {name!r}; have {sorted(registry)}")
    return registry[name](**kwargs)


def make_arrival(spec: Union[str, Tuple, "PoissonArrivals"]) -> Any:
    return _make(ARRIVALS, spec, "arrival process")


def generate_workload(n_jobs: int,
                      arrival: Any = "poisson",
                      demand: Any = None,
                      lengths: Any = None,
                      batch_ratio: Optional[float] = None,
                      seed: int = 0) -> List[JobRecord]:
    """Production-traffic generator: Table-I templates under a pluggable
    arrival process, optional heavy-tailed stage->model ``demand`` remapping
    (spanning the full zoo instead of the templates' fixed bindings), and
    optional heavy-tailed ``lengths`` overriding the lognormal draws.

    Arrival times and stage bodies come from independent
    ``np.random.default_rng([seed, k])`` streams, so the same seed gives a
    byte-identical trace for any fixed knob combination, and changing one
    knob (e.g. the arrival process) never reshuffles the others."""
    arrival = make_arrival(arrival)
    demand = _make(DEMANDS, demand, "demand distribution")
    lengths = _make(LENGTHS, lengths, "length distribution")
    arrivals = arrival.sample(np.random.default_rng([seed, 1]), n_jobs)
    rng = np.random.default_rng([seed, 2])

    weights = np.array([a.weight for a in APPS])
    if batch_ratio is not None:
        is_b = np.array([not a.interactive for a in APPS])
        w = weights.copy()
        w[is_b] *= batch_ratio / max(w[is_b].sum(), 1e-9)
        w[~is_b] *= (1 - batch_ratio) / max(w[~is_b].sum(), 1e-9)
        weights = w
    weights = weights / weights.sum()

    jobs: List[JobRecord] = []
    sid = 0
    for j in range(n_jobs):
        app = APPS[rng.choice(len(APPS), p=weights)]
        stages: List[StageRecord] = []
        tmpl_to_last: Dict[int, List[int]] = {}
        invocation = 0
        for ti, st in enumerate(app.stages):
            dep_ids: List[int] = []
            for d in st.deps:
                dep_ids += tmpl_to_last.get(d, [])
            copies = st.fanout if st.fanout > 1 else 1
            ids = []
            for c in range(copies):
                reps = 1
                while st.loop > 0 and rng.random() < st.loop and reps < 4:
                    reps += 1
                prev = list(dep_ids)
                for r in range(reps):
                    complexity = float(rng.random())
                    tool_call = bool(st.tools_available > 0
                                     and rng.random() < st.p_tool)
                    if tool_call:
                        L = int(np.clip(
                            rng.lognormal(np.log(st.tool_len), 0.25), 4, 8192))
                    elif lengths is not None:
                        L = lengths.output_len(rng)
                    else:
                        sig = 0.42 * st.sigma + (0.22 if st.cot else 0.0)
                        L = int(np.clip(rng.lognormal(
                            np.log(st.base_len * (0.4 + 2.2 * complexity)),
                            sig), 4, 8192))
                    if lengths is not None:
                        P = lengths.prompt_len(rng)
                    else:
                        P = int(np.clip(rng.lognormal(
                            np.log(st.prompt_base), 0.4), 16, 16384))
                    model_id = (demand.model_id(rng) if demand is not None
                                else st.model_id)
                    obs = StageObservation(
                        app=APP_ID[app.name], role=ROLE_ID[st.role],
                        position=ti / max(len(app.stages) - 1, 1),
                        invocation_idx=invocation,
                        tools_available=st.tools_available,
                        cot=st.cot, prompt_len=P, model_id=model_id,
                        text=_prompt_text(rng, st.role, complexity, P),
                        src_cluster=int(rng.integers(0, 3)))
                    rec = StageRecord(job_id=j, stage_id=sid, deps=prev,
                                      obs=obs, interactive=app.interactive,
                                      true_len=L, tool_call=tool_call)
                    stages.append(rec)
                    prev = [sid]
                    sid += 1
                    invocation += 1
                ids += prev
            tmpl_to_last[ti] = ids
        jobs.append(JobRecord(job_id=j, app=app.name,
                              interactive=app.interactive,
                              arrival_s=float(arrivals[j]), stages=stages))
    return jobs


# Named scenario presets for the tail-metric benchmark suite. Rates are
# tuned for the reduced-config live fleet; ``rate_scale`` sweeps them.
TAIL_SCENARIOS: Dict[str, Dict[str, Any]] = {
    # day/night sinusoid; moderately skewed demand over the full zoo
    "diurnal": dict(
        arrival=("diurnal", dict(base_rate=0.6, peak_rate=6.0,
                                 period_s=90.0)),
        demand=("zipf", dict(alpha=1.4, n_models=10))),
    # on/off bursts whose peak rate exceeds fleet capacity: the overload
    # regime where admission control and shedding differentiate policies
    "bursty-overload": dict(
        arrival=("mmpp", dict(rates=(0.8, 16.0), dwell_s=(24.0, 8.0))),
        demand=("zipf", dict(alpha=0.9, n_models=10)),
        lengths=("pareto", dict(out_alpha=1.4))),
    # steady arrivals, but heavy-tailed demand AND lengths across all ten
    # model families (vision, MoE, SSM, whisper included)
    "heavy-tail-zoo": dict(
        arrival=("poisson", dict(rate=2.5)),
        demand=("zipf", dict(alpha=1.2, n_models=10)),
        lengths=("pareto", dict())),
}


def scenario_workload(name: str, n_jobs: int, seed: int = 0,
                      rate_scale: float = 1.0) -> List[JobRecord]:
    """Instantiate a named ``TAIL_SCENARIOS`` preset at ``n_jobs`` jobs.
    ``rate_scale`` multiplies every arrival rate (smoke runs scale down)."""
    if name not in TAIL_SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(TAIL_SCENARIOS)}")
    spec = TAIL_SCENARIOS[name]
    arrival = make_arrival(spec["arrival"])
    if rate_scale != 1.0:
        arrival = arrival.scaled(rate_scale)
    return generate_workload(
        n_jobs, arrival=arrival, demand=spec.get("demand"),
        lengths=spec.get("lengths"), seed=seed)


def workload_fingerprint(jobs: Sequence[JobRecord]) -> str:
    """Hash every field of every job/stage (floats at full repr precision)
    into a short hex digest — the byte-reproducibility contract for the
    deterministic-workload tests."""
    h = hashlib.blake2b(digest_size=16)
    for j in jobs:
        h.update(repr((j.job_id, j.app, j.interactive, j.arrival_s,
                       j.deadline_s)).encode())
        for s in j.stages:
            h.update(repr((s.job_id, s.stage_id, tuple(s.deps),
                           s.interactive, s.true_len, s.tool_call,
                           s.prompt_blocks,
                           dataclasses.astuple(s.obs))).encode())
    return h.hexdigest()
