"""Multi-agent workflow trace generation (§IV.A).

Instantiates jobs from the Table-I templates with Poisson arrivals, unrolls
loops / fan-outs into stage DAGs, and samples ground-truth prompt/output
lengths with learnable structure:

  L ~ tool-call?  LogNormal(ln tool_len, 0.35)
      otherwise   LogNormal(ln base_len * (1 + complexity), sigma + 0.35*cot)

``complexity`` is a latent in [0,1] EXPRESSED IN THE PROMPT TEXT via signal
vocabulary — recoverable only through the semantic encoder (drives the
Table-VII ablation). The batch ratio can be re-weighted to sweep Fig. 7's
x-axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.predictor.features import StageObservation
from repro.data.apps import APPS, APP_ID, MODELS, ROLE_ID, AppTemplate

_FILLER = ("the a of to and on for with into from about please could review "
           "data result answer item report note info step check list").split()
_COMPLEX_WORDS = ("thorough detailed comprehensive intricate elaborate "
                  "multifaceted exhaustive rigorous").split()
_SIMPLE_WORDS = "brief quick short simple concise minimal".split()
_TOPIC = ("travel menu booking flight code bug patch news market translation "
          "meeting schedule health recipe budget analysis").split()


@dataclasses.dataclass
class StageRecord:
    job_id: int
    stage_id: int
    deps: List[int]
    obs: StageObservation
    interactive: bool
    # ground truth (hidden from the scheduler until completion)
    true_len: int
    tool_call: bool

    @property
    def model(self) -> str:
        return MODELS[self.obs.model_id]


@dataclasses.dataclass
class JobRecord:
    job_id: int
    app: str
    interactive: bool
    arrival_s: float
    stages: List[StageRecord]
    deadline_s: float = 0.0   # filled by the SLO profiler


def _prompt_text(rng, role: str, complexity: float, n_words: int) -> str:
    total = min(160, max(16, n_words // 8))
    # complexity expressed as a DENSITY of signal vocabulary (so the
    # window-mean-pooled embedding amplitude tracks it at any prompt length)
    n_sig = int(round(complexity * 0.35 * total))
    n_simple = int(round((1.0 - complexity) * 0.15 * total))
    words = list(rng.choice(_COMPLEX_WORDS, n_sig))
    words += list(rng.choice(_SIMPLE_WORDS, n_simple))
    words += list(rng.choice(_TOPIC, 3))
    words += list(rng.choice(_FILLER, max(4, total - len(words))))
    rng.shuffle(words)
    return " ".join(words)


def generate_trace(n_jobs: int, rate: float = 1.0,
                   batch_ratio: Optional[float] = None,
                   seed: int = 0) -> List[JobRecord]:
    """Poisson arrivals at `rate` jobs/s. batch_ratio rebalances the app mix
    (None keeps Table-I proportions)."""
    rng = np.random.default_rng(seed)
    weights = np.array([a.weight for a in APPS])
    if batch_ratio is not None:
        is_b = np.array([not a.interactive for a in APPS])
        w = weights.copy()
        w[is_b] *= batch_ratio / max(w[is_b].sum(), 1e-9)
        w[~is_b] *= (1 - batch_ratio) / max(w[~is_b].sum(), 1e-9)
        weights = w
    weights = weights / weights.sum()

    jobs: List[JobRecord] = []
    t = 0.0
    sid = 0
    for j in range(n_jobs):
        t += rng.exponential(1.0 / rate)
        app = APPS[rng.choice(len(APPS), p=weights)]
        stages: List[StageRecord] = []
        # unroll the template (loops + fanout) into a concrete DAG
        tmpl_to_last: Dict[int, List[int]] = {}  # template idx -> stage ids
        invocation = 0
        for ti, st in enumerate(app.stages):
            dep_ids: List[int] = []
            for d in st.deps:
                dep_ids += tmpl_to_last.get(d, [])
            copies = st.fanout if st.fanout > 1 else 1
            ids = []
            for c in range(copies):
                reps = 1
                while st.loop > 0 and rng.random() < st.loop and reps < 4:
                    reps += 1
                prev = list(dep_ids)
                for r in range(reps):
                    complexity = float(rng.random())
                    tool_call = bool(st.tools_available > 0
                                     and rng.random() < st.p_tool)
                    if tool_call:
                        L = rng.lognormal(np.log(st.tool_len), 0.25)
                    else:
                        # complexity (expressed in the prompt text) drives a
                        # ~6x dynamic range; residual lognormal noise is wider
                        # under CoT (heavy tail, Observation-1 / Fig. 1)
                        sig = 0.42 * st.sigma + (0.22 if st.cot else 0.0)
                        L = rng.lognormal(
                            np.log(st.base_len * (0.4 + 2.2 * complexity)), sig)
                    L = int(np.clip(L, 4, 8192))
                    P = int(np.clip(rng.lognormal(
                        np.log(st.prompt_base), 0.4), 16, 16384))
                    obs = StageObservation(
                        app=APP_ID[app.name], role=ROLE_ID[st.role],
                        position=ti / max(len(app.stages) - 1, 1),
                        invocation_idx=invocation,
                        tools_available=st.tools_available,
                        cot=st.cot, prompt_len=P, model_id=st.model_id,
                        text=_prompt_text(rng, st.role, complexity, P),
                        src_cluster=int(rng.integers(0, 3)))
                    rec = StageRecord(job_id=j, stage_id=sid, deps=prev,
                                      obs=obs, interactive=app.interactive,
                                      true_len=L, tool_call=tool_call)
                    stages.append(rec)
                    prev = [sid]
                    sid += 1
                    invocation += 1
                ids += prev
            tmpl_to_last[ti] = ids
        jobs.append(JobRecord(job_id=j, app=app.name,
                              interactive=app.interactive,
                              arrival_s=t, stages=stages))
    return jobs


def flatten_stages(jobs: Sequence[JobRecord]) -> List[StageRecord]:
    return [s for j in jobs for s in j.stages]


def stratified_temporal_split(jobs: Sequence[JobRecord], test_frac: float = 0.2
                              ) -> Tuple[List[StageRecord], List[StageRecord]]:
    """§IV.A: within each (agent, tool-use, thinking-mode) group, the latest
    test_frac of records are the test set."""
    groups: Dict[Tuple, List[StageRecord]] = {}
    for s in flatten_stages(jobs):
        groups.setdefault(
            (s.obs.role, s.tool_call, s.obs.cot), []).append(s)
    train, test = [], []
    for g in groups.values():
        g = sorted(g, key=lambda s: s.stage_id)
        k = max(1, int(len(g) * test_frac))
        train += g[:-k]
        test += g[-k:]
    train.sort(key=lambda s: s.stage_id)
    test.sort(key=lambda s: s.stage_id)
    return train, test
