"""Fleet membership plane: liveness tracking the gateway owns.

The gateway's view of its worker fleet was static — the fleet it was built
with, assumed alive forever. :class:`FleetRegistry` makes membership a
first-class, time-varying fact:

- **Heartbeats are piggybacked, not extra traffic.** Any consumed reply
  (poll reports, submit acks, step replies) proves the worker alive, so
  the gateway records a beat whenever a node's reply counter advanced
  since the last membership sweep. Only a node that was *silent* for a
  whole sweep gets an explicit idle-period ping
  (``NodeHandle.ping_send``) — busy fleets pay zero extra round trips.
- **Liveness state machine**: ``healthy -> suspect -> dead`` on heartbeat
  age (configurable timeouts), with recovery ``suspect -> healthy`` on any
  fresh beat. A node the :class:`~repro.distributed.fault.StragglerDetector`
  flags (its EWMA step time is a z-score outlier against the fleet) is
  demoted to ``suspect`` even while its heartbeats are current — slow is
  the precursor of dead, and ``suspect`` is the signal an external
  autoscaler (or ElasticController policy) keys on.
- **Death is decided here, handled by the gateway**: transport EOF
  (``WorkerDied``) or heartbeat timeout marks the member ``dead``; the
  gateway then evacuates — in-flight stages re-enter the ready queue as
  not-yet-dispatched, per-node prefix/reservation state is written off,
  and the death lands in telemetry as a typed ``NodeDeathEvent``.
- **Elastic membership**: ``register``/``retire`` admit and drain nodes
  mid-run, so a wall-clock fleet can grow and shrink under load.

Timeouts are denominated in *gateway clock* seconds and the sweep runs
only under the wall clock (virtual time advances while workers compute in
real time, so any virtual-time liveness deadline would be meaningless and
break the bit-identical parity contract). Under the virtual clock the only
death signal is transport EOF — which is also the only one that can
actually fire there.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.distributed.fault import StragglerDetector

HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
RETIRED = "retired"


@dataclasses.dataclass
class HeartbeatConfig:
    """Membership timing knobs (gateway-clock seconds)."""
    #: membership sweep cadence; also how often a silent node is pinged
    interval_s: float = 0.25
    #: heartbeat age that demotes healthy -> suspect
    suspect_after_s: float = 1.0
    #: heartbeat age that declares a member dead (evacuation follows)
    dead_after_s: float = 5.0

    def __post_init__(self):
        if not (0 < self.interval_s <= self.suspect_after_s
                <= self.dead_after_s):
            raise ValueError(
                f"need 0 < interval_s <= suspect_after_s <= dead_after_s, "
                f"got {self.interval_s}/{self.suspect_after_s}/"
                f"{self.dead_after_s}")


@dataclasses.dataclass
class MemberRecord:
    """One node's membership history."""
    node_id: int
    joined_t: float
    state: str = HEALTHY
    last_beat_t: float = 0.0
    beats: int = 0
    suspect_since: Optional[float] = None
    suspect_cause: str = ""
    died_t: Optional[float] = None
    death_cause: str = ""
    #: how many members have held this node id (1 = original; each
    #: re-registration after a death — a replacement worker reusing the
    #: id — increments it, so fault-injection suites can tell a rejoined
    #: fleet from one that never broke)
    generation: int = 1


class FleetRegistry:
    """Liveness bookkeeping for the gateway's worker fleet. Pure state
    machine over explicit ``now`` values — no clock of its own, so it is
    equally testable under virtual and wall time."""

    def __init__(self, cfg: Optional[HeartbeatConfig] = None,
                 detector: Optional[StragglerDetector] = None):
        self.cfg = cfg or HeartbeatConfig()
        self.detector = detector
        self.members: Dict[int, MemberRecord] = {}
        #: node ids in death order (a node re-registered after dying — a
        #: replacement reusing the id — can appear more than once)
        self.deaths: List[int] = []
        #: post-death re-registrations (replacement workers), in join order
        self.rejoins: List[int] = []

    # ---------------------------------------------------------- membership
    def register(self, node_id: int, now: float) -> MemberRecord:
        """Admit a node (fleet construction or mid-run elasticity). A dead
        member's id may be re-registered — that is reconnect: a replacement
        worker joining under the same node id, tracked as a new generation
        of the member."""
        prev = self.members.get(node_id)
        rec = MemberRecord(node_id=node_id, joined_t=now, last_beat_t=now)
        if prev is not None:
            rec.generation = prev.generation + 1
            if prev.state == DEAD:
                self.rejoins.append(node_id)
        self.members[node_id] = rec
        return rec

    def retire(self, node_id: int, now: float) -> None:
        """Graceful drain: the node leaves the fleet without a death event."""
        rec = self.members.get(node_id)
        if rec is not None and rec.state != DEAD:
            rec.state = RETIRED
        if self.detector is not None:
            self.detector.forget(node_id)

    def mark_dead(self, node_id: int, now: float,
                  cause: str = "transport failure") -> None:
        """Declare a member dead (transport EOF or timeout sweep)."""
        rec = self.members.get(node_id)
        if rec is None or rec.state in (DEAD, RETIRED):
            return
        rec.state = DEAD
        rec.died_t = now
        rec.death_cause = cause
        self.deaths.append(node_id)
        if self.detector is not None:
            self.detector.forget(node_id)

    # ------------------------------------------------------------ liveness
    def beat(self, node_id: int, now: float) -> None:
        """Record proof of life (a consumed reply or ping ack)."""
        rec = self.members.get(node_id)
        if rec is None or rec.state in (DEAD, RETIRED):
            return
        rec.last_beat_t = now
        rec.beats += 1

    def observe_step(self, node_id: int, step_s: float) -> None:
        """Feed one wall-clock engine-step observation to the straggler
        detector (per-node ``worker_step_wall_s`` deltas)."""
        if self.detector is not None and step_s > 0:
            self.detector.observe(node_id, step_s)

    def update(self, now: float) -> List[int]:
        """One membership sweep: age heartbeats through the state machine
        and fold in straggler demotions. Returns node ids newly declared
        dead by timeout (the caller evacuates them)."""
        slow = (set(self.detector.stragglers())
                if self.detector is not None else set())
        newly_dead: List[int] = []
        for nid, rec in self.members.items():
            if rec.state in (DEAD, RETIRED):
                continue
            age = now - rec.last_beat_t
            if age >= self.cfg.dead_after_s:
                self.mark_dead(
                    nid, now,
                    cause=f"heartbeat timeout ({age:.2f}s silent)")
                newly_dead.append(nid)
            elif age >= self.cfg.suspect_after_s or nid in slow:
                if rec.state != SUSPECT:
                    rec.state = SUSPECT
                    rec.suspect_since = now
                    rec.suspect_cause = ("straggler" if nid in slow
                                         else f"heartbeat age {age:.2f}s")
            elif rec.state == SUSPECT:
                rec.state = HEALTHY        # fresh beat + not slow: recover
                rec.suspect_since = None
                rec.suspect_cause = ""
        return newly_dead

    # ------------------------------------------------------------- queries
    def state(self, node_id: int) -> str:
        return self.members[node_id].state

    def states(self) -> Dict[int, str]:
        return {nid: rec.state for nid, rec in sorted(self.members.items())}

    def live(self) -> List[int]:
        return [nid for nid, rec in sorted(self.members.items())
                if rec.state in (HEALTHY, SUSPECT)]

    def suspects(self) -> List[int]:
        return [nid for nid, rec in sorted(self.members.items())
                if rec.state == SUSPECT]

    def stragglers(self) -> List[int]:
        """Live nodes the detector currently flags (wall clock only — the
        observations are real seconds)."""
        if self.detector is None:
            return []
        alive = {nid for nid, rec in self.members.items()
                 if rec.state in (HEALTHY, SUSPECT)}
        return sorted(n for n in self.detector.stragglers() if n in alive)
