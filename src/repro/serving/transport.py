"""Socket transport: length-prefixed, versioned TCP framing of the worker
request/reply protocol.

The process backend (PR 4) speaks its protocol over ``multiprocessing``
pipes, which confines the fleet to one host. This module provides the same
connection surface — ``send`` / ``recv`` / ``poll`` / ``close``, blocking
FIFO request/reply semantics — over a TCP socket, so a ``NodeRuntime``
worker can live on any reachable machine while the gateway-side protocol
machinery (:class:`repro.serving.worker.NodeHandle`) runs unchanged.

Framing: every frame is a fixed 12-byte header followed by the payload::

    !4s  B    xxx  I        MAGIC  b"MAES"
    magic ver pad  length   FRAME_VERSION 1 (bumped on any wire change)

Both magic and version are validated on every frame, so a cross-version
gateway/worker pair fails with a typed :class:`ProtocolVersionError`
instead of desynchronizing mid-stream. On top of the framing sits a small
codec seam (:class:`Codec`): payloads default to pickle
(:class:`PickleCodec`) because the protocol ships plain dataclasses
(``Request``, ``NodeSignal``, ``WorkerSpec``) exactly as the pipes did.

SECURITY: pickle executes arbitrary code at load time. This transport is a
*trusted-network* fabric (the same trust model as the multiprocessing
pipes it generalizes) — run workers only on hosts and networks you
control, never exposed to untrusted peers. A hardened codec can be slotted
in behind the :class:`Codec` seam without touching the protocol.

The transport counts bytes and frames in both directions
(``bytes_sent`` / ``bytes_recv``), which the gateway surfaces as the
per-node transport-overhead columns in ``BENCH_gateway_socket.json``.
"""
from __future__ import annotations

import pickle
import select
import socket
import struct
import time
from typing import Any, Optional, Protocol, Tuple

MAGIC = b"MAES"
#: bumped on ANY wire-format change; validated on every frame
FRAME_VERSION = 1
_HEADER = struct.Struct("!4sBxxxI")           # magic, version, pad, length
#: sanity bound on one frame's payload (a corrupt length prefix must not
#: make the receiver try to allocate terabytes)
MAX_FRAME_BYTES = 1 << 30


class TransportError(RuntimeError):
    """Wire-level framing violation (bad magic, oversized frame)."""


class ProtocolVersionError(TransportError):
    """Peer speaks a different FRAME_VERSION; fail typed, not garbled."""


class Codec(Protocol):
    """Payload (de)serialization seam under the framing layer."""

    name: str

    def dumps(self, obj: Any) -> bytes: ...

    def loads(self, data: bytes) -> Any: ...


class PickleCodec:
    """Default codec: pickle, exactly what the multiprocessing pipes used
    (trusted-network only — see module docstring)."""

    name = "pickle"

    def dumps(self, obj: Any) -> bytes:
        return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)

    def loads(self, data: bytes) -> Any:
        return pickle.loads(data)


class FrameTransport:
    """One framed, codec'd TCP connection with ``multiprocessing.Connection``
    semantics: blocking ``recv`` of whole objects, ``poll(timeout)`` for
    readability, ``EOFError`` when the peer is gone. Drop-in for the pipe
    inside :class:`repro.serving.worker.NodeHandle` and ``_worker_main``."""

    def __init__(self, sock: socket.socket, codec: Optional[Codec] = None):
        sock.settimeout(None)                  # blocking; poll() does waits
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:                         # pragma: no cover
            pass                                # non-TCP test doubles
        self._sock = sock
        self.codec: Codec = codec or PickleCodec()
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.frames_sent = 0
        self.frames_recv = 0
        self._closed = False

    # ------------------------------------------------------------- protocol
    def send(self, obj: Any) -> None:
        payload = self.codec.dumps(obj)
        frame = _HEADER.pack(MAGIC, FRAME_VERSION, len(payload)) + payload
        self._sock.sendall(frame)
        self.bytes_sent += len(frame)
        self.frames_sent += 1

    def recv(self) -> Any:
        hdr = self._recv_exact(_HEADER.size)
        magic, version, length = _HEADER.unpack(hdr)
        if magic != MAGIC:
            raise TransportError(
                f"bad frame magic {magic!r} (expected {MAGIC!r}) — peer is "
                f"not a maestro worker transport")
        if version != FRAME_VERSION:
            raise ProtocolVersionError(
                f"frame version {version} != local {FRAME_VERSION} — "
                f"gateway and worker builds are incompatible")
        if length > MAX_FRAME_BYTES:
            raise TransportError(f"frame length {length} exceeds "
                                 f"{MAX_FRAME_BYTES} — corrupt stream")
        payload = self._recv_exact(length)
        self.bytes_recv += _HEADER.size + length
        self.frames_recv += 1
        return self.codec.loads(payload)

    def poll(self, timeout: Optional[float] = 0.0) -> bool:
        """True when a frame (or EOF) is readable. ``timeout=None`` blocks;
        EOF counts as readable so a dead peer is noticed immediately, like
        a pipe whose writer exited."""
        if self._closed:
            raise OSError("transport is closed")
        r, _, _ = select.select([self._sock], [], [], timeout)
        return bool(r)

    def _recv_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("socket closed by peer")
            buf += chunk
        return bytes(buf)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass                                # already reset/closed
        self._sock.close()

    def fileno(self) -> int:
        return self._sock.fileno()


# ---------------------------------------------------------------------------
# connection helpers
# ---------------------------------------------------------------------------

def parse_address(text: str) -> Tuple[str, int]:
    """``"host:port"`` -> ``(host, port)`` (the ``--listen`` CLI format)."""
    host, _, port = text.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"expected HOST:PORT, got {text!r}")
    return host, int(port)


def listen(host: str, port: int, backlog: int = 8) -> socket.socket:
    """Bound + listening server socket (``port=0`` picks an ephemeral
    port; read it back from ``sock.getsockname()[1]``)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(backlog)
    return srv


def accept(srv: socket.socket,
           codec: Optional[Codec] = None) -> FrameTransport:
    sock, _peer = srv.accept()
    return FrameTransport(sock, codec=codec)


def connect(address: Tuple[str, int], timeout_s: float = 30.0,
            retry_s: float = 0.05,
            codec: Optional[Codec] = None) -> FrameTransport:
    """Connect to a listening worker, retrying briefly (a worker started a
    moment ago may not have reached ``listen`` yet)."""
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            sock = socket.create_connection(address, timeout=timeout_s)
            return FrameTransport(sock, codec=codec)
        except OSError:
            if time.monotonic() >= deadline:
                raise
            time.sleep(retry_s)
