"""Cross-cluster serving gateway: the LIVE plane of the Maestro hierarchy.

``ClusterGateway`` owns a fleet of real ``NodeRuntime`` engines spread over
simulated-RTT clusters and serves multi-stage workflow DAGs end-to-end
through the paper's full pipeline:

  global workflow-aware SRTF queue (Eq. 7-8) with boundary preemption
    -> fitness routing over live NodeSignals (Eq. 5-6, Alg. 3)
    -> rho-margin admission against each node's MemoryAccountant (§III.C)
    -> real continuous-batching execution on the node engines
    -> post-execution calibration back into rho + the WorkflowProfileStore.

The event loop is STEP-DRIVEN: one ``step()`` advances a virtual clock by
``tick_s`` and runs one iteration of every busy engine. Network RTT and
cold-start activation enter as deterministic virtual delays (a dispatched
stage reaches its engine only after rtt + T_act of virtual time), so runs
are reproducible and unit-testable — no wall-clock sleeps anywhere.

Pluggable policies (fcfs / least-loaded / maestro) reproduce the simulator's
controlled comparison on real engines: all policies share the fleet, the
admission substrate and the arrival trace; they differ only in queue order,
routing and preemption.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.control_loop import MaestroController, model_name
from repro.core.sched.fitness import NodeSignal, StageRequest
from repro.core.sched.srtf import QueuedStage, SRTFQueue, state_key
from repro.serving.cluster import LiveJob, LiveStage
from repro.serving.engine import Request
from repro.serving.node_runtime import NodeRuntime
from repro.serving.telemetry import GatewayMetrics, Telemetry

COLD_START_THRESHOLD_S = 0.01


@dataclasses.dataclass
class GatewayConfig:
    tick_s: float = 0.05              # virtual seconds per engine iteration
    interactive_budget_s: float = 1.5  # per-job interactive wait SLO
    slo_factor: float = 3.0            # batch deadline = factor * isolated
    static_reserve_tokens: int = 64    # non-predictive KV reservation (fcfs/ll)
    max_inflight_per_node: Optional[int] = None   # default: node max_slots
    reject_limit: int = 1000           # routing failures before job drop
    preempt_gain_ticks: float = 2.0    # SRTF hysteresis, in ticks
    preempt_cooldown_ticks: float = 10.0
    refresh_every: int = 8             # aging refresh period (ticks)
    headroom_sample_every: int = 10


@dataclasses.dataclass
class _InFlight:
    stage: LiveStage
    node_id: int
    model: str
    req: Request
    submit_at: float                  # virtual time the engine may see it
    submitted: bool = False


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

class GatewayPolicy:
    """Queue order + routing. Bound to one gateway instance."""
    name = "base"
    preemptive = False

    def bind(self, gw: "ClusterGateway") -> None:
        self.gw = gw

    def push(self, stage: LiveStage, now: float) -> None:
        raise NotImplementedError

    def peek(self, now: float) -> Optional[LiveStage]:
        raise NotImplementedError

    def pop(self, now: float) -> Optional[LiveStage]:
        raise NotImplementedError

    def discard(self, stage: LiveStage) -> None:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def refresh(self, now: float) -> None:
        pass

    def plan(self, stage: LiveStage, now: float
             ) -> Tuple[Optional[int], Dict[str, float]]:
        """Returns (node_id or None, meta: r_need / l_hat / t_act / rtt)."""
        raise NotImplementedError

    def on_finish(self, stage: LiveStage, out_len: int, now: float) -> None:
        pass

    # -------------------------------------------------- shared helpers
    def _static_r_need(self, stage: LiveStage) -> float:
        prof = self.gw.profiles[self.gw.model_of(stage)]
        return prof.r_kv(len(stage.tokens),
                         self.gw.cfg.static_reserve_tokens)

    def _feasible(self, nid: int, r_need: float) -> bool:
        gw = self.gw
        return (gw.node_load[nid] < gw.inflight_cap[nid]
                and gw.fleet[nid].acc.can_admit(r_need))


class FCFSPolicy(GatewayPolicy):
    """Global FIFO + first feasible node; static KV reservation."""
    name = "fcfs"

    def __init__(self) -> None:
        self.q: Deque[LiveStage] = collections.deque()

    def push(self, stage, now):
        self.q.append(stage)

    def peek(self, now):
        return self.q[0] if self.q else None

    def pop(self, now):
        return self.q.popleft() if self.q else None

    def discard(self, stage):
        try:
            self.q.remove(stage)
        except ValueError:
            pass

    def __len__(self):
        return len(self.q)

    def plan(self, stage, now):
        r_need = self._static_r_need(stage)
        model = self.gw.model_of(stage)
        for nid in sorted(self.gw.fleet):
            if self._feasible(nid, r_need):
                node = self.gw.fleet[nid]
                return nid, {"r_need": r_need, "l_hat": None,
                             "t_act": node.t_act(model),
                             "rtt": self.gw.rtt(stage, nid)}
        return None, {"r_need": r_need}


class LeastLoadedPolicy(FCFSPolicy):
    """Global FIFO + least-inflight feasible node."""
    name = "least-loaded"

    def plan(self, stage, now):
        r_need = self._static_r_need(stage)
        model = self.gw.model_of(stage)
        cands = [nid for nid in self.gw.fleet
                 if self._feasible(nid, r_need)]
        if not cands:
            return None, {"r_need": r_need}
        nid = min(cands, key=lambda n: (self.gw.node_load[n], n))
        return nid, {"r_need": r_need, "l_hat": None,
                     "t_act": self.gw.fleet[nid].t_act(model),
                     "rtt": self.gw.rtt(stage, nid)}


class MaestroPolicy(GatewayPolicy):
    """Workflow-aware SRTF + fitness routing + rho-margin admission +
    boundary preemption — the full hierarchy on live engines."""
    name = "maestro"
    preemptive = True

    def __init__(self, ctl: MaestroController) -> None:
        self.ctl = ctl
        self.entries: Dict[int, QueuedStage] = {}   # stage_id -> queue entry
        self.preds: Dict[int, Dict[str, float]] = {}

    # ------------------------------------------------------------ prediction
    def _pred(self, stage: LiveStage) -> Dict[str, float]:
        p = self.preds.get(stage.stage_id)
        if p is None:
            l_hat, p_tool, r_kv_hat = self.ctl.predict_stage(stage.obs)
            p = {"l_hat": l_hat, "p_tool": p_tool, "r_kv_hat": r_kv_hat}
            self.preds[stage.stage_id] = p
        return p

    def _t_exec_v(self, stage: LiveStage, l_hat: float) -> float:
        """Predicted stage duration in VIRTUAL seconds (prefill tick +
        one decode tick per predicted token, capped by the decode budget)."""
        return self.gw.cfg.tick_s * (1.0 + min(l_hat, stage.max_new))

    # ------------------------------------------------------------ queue ops
    def push(self, stage, now):
        p = self._pred(stage)
        key = state_key(stage.obs.app, stage.obs.role,
                        stage.obs.invocation_idx, p["p_tool"])
        qs = QueuedStage(stage_id=stage.stage_id, job_id=stage.job_id,
                         interactive=stage.interactive,
                         t_exec=self._t_exec_v(stage, p["l_hat"]),
                         t_future=self.ctl.wf_profiles.future_median(key),
                         enqueue_time=now)
        self.entries[stage.stage_id] = qs
        self.ctl.queue.push(qs, now)

    def peek(self, now):
        qs = self.ctl.queue.peek()
        return None if qs is None else self.gw.stage_by_id[qs.stage_id]

    def pop(self, now):
        qs = self.ctl.queue.pop(now)
        if qs is None:
            return None
        self.entries.pop(qs.stage_id, None)
        return self.gw.stage_by_id[qs.stage_id]

    def discard(self, stage):
        qs = self.entries.pop(stage.stage_id, None)
        if qs is not None:
            self.ctl.queue.remove(qs)

    def __len__(self):
        return len(self.ctl.queue)

    def refresh(self, now):
        self.ctl.queue.refresh(now)

    # --------------------------------------------------------------- routing
    def plan(self, stage, now):
        gw = self.gw
        p = self._pred(stage)
        r_need = self.ctl.rho.r_need(p["r_kv_hat"])
        model = gw.model_of(stage)
        prof = gw.profiles[model]
        req = StageRequest(
            stage_id=stage.stage_id, model=model, r_need=r_need,
            interactive=stage.interactive,
            src_cluster=stage.obs.src_cluster,
            t_exec=prof.t_exec(stage.obs.prompt_len, p["l_hat"]))
        signals = [gw.signal(nid) for nid in gw.fleet
                   if gw.node_load[nid] < gw.inflight_cap[nid]]
        sel = self.ctl.router.select(
            req, signals,
            t_act_of=lambda sig, m: gw.fleet[sig.node_id].t_act(m),
            c_deg_of=lambda sig, rq: None)   # no live degradation plans yet
        if sel is None:
            return None, {"r_need": r_need, "l_hat": p["l_hat"]}
        nid = sel[0].node_id
        return nid, {"r_need": r_need, "l_hat": p["l_hat"],
                     "t_act": gw.fleet[nid].t_act(model),
                     "rtt": gw.rtt(stage, nid), "score": sel[1]}

    # ----------------------------------------------------------- calibration
    def on_finish(self, stage, out_len, now):
        p = self._pred(stage)
        prof = self.gw.profiles[self.gw.model_of(stage)]
        # Calibrate on the SAME basis the prediction used (the uncapped
        # trace-scale lengths): the realized output, mapped back through the
        # live decode budget, against L_hat. Comparing live capped bytes to
        # the uncapped R_kv_hat would make the error identically zero and
        # pin rho to its floor.
        nominal = stage.nominal_len or stage.max_new
        actual_len = nominal * out_len / max(stage.max_new, 1)
        actual_kv = prof.r_kv(stage.obs.prompt_len, actual_len)
        self.ctl.rho.observe(actual_kv, max(p["r_kv_hat"], 1.0))
        key = state_key(stage.obs.app, stage.obs.role,
                        stage.obs.invocation_idx, p["p_tool"])
        self.ctl.wf_profiles.record(key, self.gw.job_remaining_v(stage))


# ---------------------------------------------------------------------------
# The gateway
# ---------------------------------------------------------------------------

def make_policy(name: str, ctl: Optional[MaestroController]) -> GatewayPolicy:
    if name == "fcfs":
        return FCFSPolicy()
    if name == "least-loaded":
        return LeastLoadedPolicy()
    if name == "maestro":
        if ctl is None:
            raise ValueError("maestro policy needs a MaestroController "
                             "(pass predictor= to ClusterGateway)")
        return MaestroPolicy(ctl)
    raise ValueError(f"unknown gateway policy {name!r}")


class ClusterGateway:
    def __init__(self, fleet: Sequence[NodeRuntime], rtt_s: np.ndarray,
                 predictor=None, policy: str = "maestro",
                 cfg: Optional[GatewayConfig] = None,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg or GatewayConfig()
        self.fleet: Dict[int, NodeRuntime] = {n.node_id: n for n in fleet}
        self.rtt_s = np.asarray(rtt_s, float)
        self.profiles = {name: p
                         for name, p in next(iter(self.fleet.values()))
                         .profiles.items()}
        self.telemetry = telemetry or Telemetry()
        self.ctl: Optional[MaestroController] = None
        if predictor is not None:
            queue = SRTFQueue(
                preempt_gain_s=self.cfg.preempt_gain_ticks * self.cfg.tick_s,
                cooldown_s=self.cfg.preempt_cooldown_ticks * self.cfg.tick_s)
            self.ctl = MaestroController(predictor, self.profiles,
                                         self.rtt_s, queue=queue)
        self.policy = make_policy(policy, self.ctl)
        self.policy.bind(self)

        # clock + workload state
        self.tick = 0
        self.stage_by_id: Dict[int, LiveStage] = {}
        self.jobs: Dict[int, LiveJob] = {}
        self.pending_deps: Dict[int, int] = {}
        self.ready_t: Dict[int, float] = {}
        self.done: set = set()
        self.job_done_stages: Dict[int, int] = {}
        self.job_finish: Dict[int, float] = {}
        self.dropped: set = set()
        self.arrivals: List[Tuple[float, int]] = []   # (arrival_s, job_id)
        self.inflight: Dict[int, _InFlight] = {}      # stage_id -> record
        self.node_load: Dict[int, int] = {nid: 0 for nid in self.fleet}
        self.inflight_cap: Dict[int, int] = {
            nid: (self.cfg.max_inflight_per_node
                  or self.fleet[nid].max_slots)
            for nid in self.fleet}
        self.qd_ewma: Dict[int, float] = {nid: 0.0 for nid in self.fleet}
        self._rejects: Dict[int, int] = collections.defaultdict(int)

    # ----------------------------------------------------------------- views
    @property
    def now(self) -> float:
        return self.tick * self.cfg.tick_s

    def model_of(self, stage: LiveStage) -> str:
        return model_name(stage.obs, self.profiles)

    def rtt(self, stage: LiveStage, nid: int) -> float:
        src = stage.obs.src_cluster % self.rtt_s.shape[0]
        return float(self.rtt_s[src, self.fleet[nid].cluster_id])

    def signal(self, nid: int) -> NodeSignal:
        """Live NodeSignal with the gateway's virtual queue-delay EWMA (the
        runtime's own queue statistic is engine-local and not in seconds)."""
        sig = self.fleet[nid].signal()
        sig.queue_delay_s = self.qd_ewma[nid]
        return sig

    def job_remaining_v(self, stage: LiveStage) -> float:
        """Remaining virtual execution time of the stage's job, AFTER this
        stage — the Eq. 8 sample recorded into the WorkflowProfileStore."""
        job = self.jobs[stage.job_id]
        return sum(self.cfg.tick_s * (1.0 + s.max_new) for s in job.stages
                   if s.stage_id not in self.done
                   and s.stage_id != stage.stage_id)

    # ------------------------------------------------------------- workload
    def submit_jobs(self, jobs: Sequence[LiveJob]) -> None:
        for j in jobs:
            self.jobs[j.job_id] = j
            self.job_done_stages.setdefault(j.job_id, 0)
            if j.deadline_s <= 0.0:
                j.deadline_s = self._deadline(j)
            self.arrivals.append((j.arrival_s, j.job_id))
            for s in j.stages:
                self.stage_by_id[s.stage_id] = s
                self.pending_deps[s.stage_id] = len(s.deps)
        self.arrivals.sort()

    def _deadline(self, job: LiveJob) -> float:
        """SLO profiling against the virtual execution model: critical-path
        time with everything warm, scaled by slo_factor."""
        finish: Dict[int, float] = {}
        for s in job.stages:
            start = max((finish[d] for d in s.deps), default=0.0)
            finish[s.stage_id] = start + self.cfg.tick_s * (2.0 + s.max_new)
        return self.cfg.slo_factor * max(finish.values())

    # ------------------------------------------------------------ event loop
    def run(self, jobs: Sequence[LiveJob],
            max_ticks: Optional[int] = None) -> GatewayMetrics:
        self.submit_jobs(jobs)
        if max_ticks is None:
            n_stage_ticks = sum(s.max_new + 6 for j in jobs
                                for s in j.stages)
            max_ticks = 40 * n_stage_ticks + 4000
        while self._unfinished() and self.tick < max_ticks:
            self.step()
        return self.metrics()

    def _unfinished(self) -> bool:
        return any(j not in self.job_finish and j not in self.dropped
                   for j in self.jobs)

    def metrics(self) -> GatewayMetrics:
        return self.telemetry.summary(
            self.policy.name, list(self.jobs.values()), self.job_finish,
            self.cfg.interactive_budget_s, self.now)

    def step(self) -> None:
        now = self.now
        # 1) arrivals: source stages of newly arrived jobs become ready
        while self.arrivals and self.arrivals[0][0] <= now:
            _, jid = self.arrivals.pop(0)
            for s in self.jobs[jid].stages:
                if not s.deps:
                    self._mark_ready(s, now)
        # 2) SRTF aging refresh
        if self.tick % self.cfg.refresh_every == 0:
            self.policy.refresh(now)
        # 3) global-queue dispatch (routing + admission + preemption)
        self._dispatch(now)
        # 4) stages whose rtt + activation virtual delay elapsed hit engines
        self._flush_submissions(now)
        # 5) one real iteration of every busy engine
        for nid, node in self.fleet.items():
            for model, reqs in node.step().items():
                for req in reqs:
                    self._on_finish(req, now)
        # 6) telemetry sampling
        if self.tick % self.cfg.headroom_sample_every == 0:
            for nid, node in self.fleet.items():
                self.telemetry.sample_headroom(nid, node.acc.headroom)
        self.tick += 1

    # -------------------------------------------------------------- phases
    def _mark_ready(self, stage: LiveStage, now: float) -> None:
        if stage.job_id in self.dropped:
            return
        self.ready_t[stage.stage_id] = now
        ev = self.telemetry.event(stage.stage_id, stage.job_id,
                                  stage.interactive)
        ev.ready_t = now
        ev.model = self.model_of(stage)
        self.policy.push(stage, now)

    def _dispatch(self, now: float) -> None:
        while len(self.policy):
            stage = self.policy.peek(now)
            if stage is None:
                break
            if stage.job_id in self.dropped or stage.stage_id in self.done:
                self.policy.pop(now)
                continue
            nid, meta = self.policy.plan(stage, now)
            if nid is None:
                # memory infeasibility (a node had a free slot yet could not
                # admit) is an ADMISSION rejection; all-slots-busy is plain
                # queueing and neither counted nor held against the job
                slots_free = any(self.node_load[n] < self.inflight_cap[n]
                                 for n in self.fleet)
                if slots_free:
                    self.telemetry.admission_rejections += 1
                    self.telemetry.event(stage.stage_id, stage.job_id,
                                         stage.interactive).rejections += 1
                    self._rejects[stage.stage_id] += 1
                if (self.policy.preemptive and stage.interactive
                        and self._try_preempt(stage, now)):
                    continue                   # retry the head post-eviction
                if self._rejects[stage.stage_id] > self.cfg.reject_limit:
                    self._drop_job(stage.job_id, now)
                    continue
                break                          # head-of-line block
            self.policy.pop(now)
            self._dispatch_to(stage, nid, meta, now)

    def _dispatch_to(self, stage: LiveStage, nid: int,
                     meta: Dict[str, float], now: float) -> None:
        node = self.fleet[nid]
        model = self.model_of(stage)
        rtt = meta.get("rtt", self.rtt(stage, nid))
        t_act = meta.get("t_act", node.t_act(model))
        if t_act > COLD_START_THRESHOLD_S:
            self.telemetry.cold_starts += 1
        l_hat = meta.get("l_hat")
        req = Request(req_id=stage.stage_id, tokens=list(stage.tokens),
                      max_new=stage.max_new,
                      pred_len=(None if l_hat is None
                                else float(min(l_hat, stage.max_new))))
        self.inflight[stage.stage_id] = _InFlight(
            stage=stage, node_id=nid, model=model, req=req,
            submit_at=now + rtt + t_act)
        self.node_load[nid] += 1
        wait = max(0.0, now - self.ready_t.get(stage.stage_id, now))
        self.qd_ewma[nid] = 0.8 * self.qd_ewma[nid] + 0.2 * (wait + t_act)
        ev = self.telemetry.event(stage.stage_id, stage.job_id,
                                  stage.interactive)
        ev.node_id, ev.dispatch_t = nid, now
        ev.rtt_s, ev.t_act_s = rtt, t_act

    def _flush_submissions(self, now: float) -> None:
        for rec in self.inflight.values():
            if rec.submitted or rec.submit_at > now + 1e-9:
                continue
            node = self.fleet[rec.node_id]
            t0 = time.perf_counter()
            node.submit(rec.model, rec.req)   # real activation on demand
            rec.submitted = True
            ev = self.telemetry.event(rec.stage.stage_id, rec.stage.job_id,
                                      rec.stage.interactive)
            ev.start_t = now
            ev.wall_act_s = time.perf_counter() - t0

    def _on_finish(self, req: Request, now: float) -> None:
        rec = self.inflight.pop(req.req_id, None)
        if rec is None:
            return
        stage = rec.stage
        self.node_load[rec.node_id] -= 1
        self.done.add(stage.stage_id)
        self._rejects.pop(stage.stage_id, None)
        ev = self.telemetry.event(stage.stage_id, stage.job_id,
                                  stage.interactive)
        ev.finish_t, ev.out_len = now, len(req.out)
        self.policy.on_finish(stage, len(req.out), now)
        job = self.jobs[stage.job_id]
        self.job_done_stages[stage.job_id] += 1
        if self.job_done_stages[stage.job_id] == len(job.stages):
            self.job_finish[stage.job_id] = now
        # successor re-queueing: every dependent whose deps are all done
        # re-enters the GLOBAL queue and contends under the policy's order
        for st in job.stages:
            if stage.stage_id in st.deps:
                self.pending_deps[st.stage_id] -= 1
                if self.pending_deps[st.stage_id] == 0:
                    self._mark_ready(st, now)

    # ---------------------------------------------------------- preemption
    def _try_preempt(self, stage: LiveStage, now: float) -> bool:
        """Boundary preemption: evict a batch stage between engine steps so
        an infeasible interactive head can place. Guarded by the SRTF
        queue's hysteresis + cooldown; the victim restarts from its prompt."""
        assert self.ctl is not None
        pol = self.policy
        cand_qs = QueuedStage(
            stage_id=stage.stage_id, job_id=stage.job_id, interactive=True,
            t_exec=self.cfg.tick_s * (1.0 + stage.max_new), t_future=0.0)
        victims = sorted(
            (r for r in self.inflight.values() if not r.stage.interactive),
            key=lambda r: -(r.stage.max_new - len(r.req.out)))
        for rec in victims:
            remaining_v = self.cfg.tick_s * max(
                1.0, 1.0 + rec.stage.max_new - len(rec.req.out))
            run_qs = QueuedStage(
                stage_id=rec.stage.stage_id, job_id=rec.stage.job_id,
                interactive=False, t_exec=remaining_v, t_future=0.0)
            if not self.ctl.queue.should_preempt(run_qs, cand_qs,
                                                 remaining_v, now):
                continue
            if rec.submitted:
                if self.fleet[rec.node_id].preempt(rec.model,
                                                   rec.req.req_id) is None:
                    continue   # finished this very tick; nothing to evict
            self.inflight.pop(rec.stage.stage_id, None)
            self.node_load[rec.node_id] -= 1
            self.telemetry.preemptions += 1
            ev = self.telemetry.event(rec.stage.stage_id, rec.stage.job_id,
                                      False)
            ev.preemptions += 1
            # bank the aborted attempt's wait before _mark_ready resets it
            ev.prior_wait_s += (max(0.0, ev.dispatch_t - ev.ready_t)
                                + ev.rtt_s + ev.t_act_s)
            ev.rtt_s = ev.t_act_s = 0.0
            self._mark_ready(rec.stage, now)   # requeue from scratch
            return True
        return False

    def _drop_job(self, job_id: int, now: float) -> None:
        """Admission gave up on this job (reject_limit exceeded): withdraw
        its queued stages so the gateway keeps serving everyone else."""
        self.dropped.add(job_id)
        self.telemetry.dropped_jobs += 1
        for s in self.jobs[job_id].stages:
            if s.stage_id not in self.done:
                self.policy.discard(s)
