"""Cross-cluster serving gateway: the LIVE plane of the Maestro hierarchy.

``ClusterGateway`` owns a fleet of real ``NodeRuntime`` engines spread over
simulated-RTT clusters and serves multi-stage workflow DAGs end-to-end
through the paper's full pipeline:

  global priority queue ordered by the POLICY (unified registry in
  ``repro.core.sched.policies`` — the same objects that drive the trace
  simulator) with boundary preemption
    -> policy routing over live NodeSignals (Eq. 5-6, Alg. 3)
    -> rho-margin admission against each node's MemoryAccountant (§III.C),
       eviction-aware: Alg. 2 degradation plans enter feasibility AND are
       executed (``NodeRuntime.make_room``) at submit time
    -> real continuous-batching execution on the node engines
    -> post-execution calibration back through ``policy.on_finish``.

The gateway is the live :class:`~repro.core.sched.substrate.Substrate`
implementation: it owns the queue mechanics, the clock and the telemetry,
while every scheduling decision (queue order, reservation, routing,
preemption) is delegated to the policy. Any registered policy name (fcfs /
least-loaded / edf / oracle-srtf / maestro / maestro-np / baseline-lb /
binpack / maestro-aff) runs on real engines.

The event loop is CLOCK-DRIVEN (:mod:`repro.serving.clock`): network RTT
and cold-start activation enter as delayed event releases on the gateway's
clock, periodic work (aging refresh, telemetry sampling) runs on
clock-owned cadences, and the run deadline (``GatewayConfig.max_run_s``)
is enforced by the clock with a typed ``RunDeadlineExceeded`` outcome.

Two clocks plug in:

- ``clock="virtual"`` (default): one loop pass advances ``tick_s`` virtual
  seconds and runs one lock-step iteration of every busy engine. Fully
  deterministic and bit-identical to the pre-clock-plane gateway on both
  node backends — no wall-clock sleeps anywhere.
- ``clock="wall"``: real monotonic time. Process-backend workers FREE-RUN
  (continuous stepping in their own processes); the gateway submits and
  polls asynchronously, so engine iterations genuinely overlap across
  processes in measured time. Queue delay and SLO attainment come out in
  real elapsed seconds; the policies' cost estimates (``t_exec_est``,
  deadline profiling) remain the nominal virtual model, so scheduling
  decisions share one code path on both clocks.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.control_loop import model_name
from repro.core.sched.fitness import NodeSignal
from repro.core.sched.policies import SchedPolicy, make_policy
from repro.core.sched.substrate import SchedStage
from repro.core.topology import validate_rtt
from repro.distributed.fault import StragglerDetector
from repro.serving.clock import (RunDeadlineExceeded, VirtualClock,
                                 make_clock)
from repro.serving.cluster import LiveJob, LiveStage
from repro.serving.engine import PromptTooLongError, Request
from repro.serving.node_runtime import NodeRuntime
from repro.serving.prefix_cache import page_digests
from repro.serving.registry import FleetRegistry, HeartbeatConfig
from repro.serving.telemetry import (GatewayMetrics, NodeDeathEvent,
                                     Telemetry)
from repro.serving.worker import WorkerDied, close_fleet

COLD_START_THRESHOLD_S = 0.01


@dataclasses.dataclass
class GatewayConfig:
    tick_s: float = 0.05              # virtual seconds per engine iteration
    interactive_budget_s: float = 1.5  # per-job interactive wait SLO
    slo_factor: float = 3.0            # batch deadline = factor * isolated
    static_reserve_tokens: int = 64    # non-predictive KV reservation (fcfs/ll)
    max_inflight_per_node: Optional[int] = None   # default: node max_slots
    reject_limit: int = 1000           # routing failures before job drop
    headroom_sample_every: int = 10    # telemetry cadence, in ticks
    # ---- clock plane ----------------------------------------------------
    # "virtual": deterministic tick clock (default — tests and all cross-PR
    # BENCH baselines). "wall": real monotonic seconds; workers free-run.
    clock: str = "virtual"
    # Run deadline in CLOCK seconds, enforced by the Clock; when exceeded
    # the metrics carry a typed RunDeadlineExceeded outcome. None = the
    # legacy workload-derived safety cap on the virtual clock, no deadline
    # on the wall clock (wall runs should set this explicitly).
    max_run_s: Optional[float] = None
    # Clock-independent policy hysteresis / cadence, in SECONDS (the
    # canonical fields — both clocks and both planes share this code path).
    # None = derived from the deprecated tick-denominated shims below.
    preempt_gain_s: Optional[float] = None     # default 2 ticks = 0.1 s
    preempt_cooldown_s: Optional[float] = None  # default 10 ticks = 0.5 s
    refresh_every_s: Optional[float] = None     # default 8 ticks = 0.4 s
    wall_poll_s: float = 0.002         # wall clock: sleep while awaiting work
    # ---- DEPRECATED tick-denominated shims ------------------------------
    # superseded by the *_s fields above; still honored (converted via
    # tick_s) so existing configs keep working, with a DeprecationWarning
    # when explicitly overridden.
    preempt_gain_ticks: float = 2.0
    preempt_cooldown_ticks: float = 10.0
    refresh_every: int = 8
    # "inproc": nodes are NodeRuntime objects cooperatively stepped inside
    # the gateway process (deterministic default — tests and the virtual
    # clock depend on it). "process": nodes are worker.NodeHandle proxies,
    # one OS process per node; under the virtual clock one tick broadcasts
    # step to every worker, under the wall clock workers free-run.
    # "socket": the same worker protocol over the framed TCP transport
    # (repro.serving.transport) — localhost children by default, or remote
    # hosts via `python -m repro.serving.worker --listen`; protocol-
    # identical to "process", so virtual-clock runs stay bit-identical.
    node_backend: str = "inproc"
    # ---- membership plane (transport backends, wall clock only) ---------
    # Heartbeat sweep cadence plus the liveness timeouts that demote a
    # silent worker healthy -> suspect -> dead (gateway-clock seconds).
    # Liveness is wall-clock-only: virtual time advances while workers
    # compute in real time, so a virtual-denominated deadline would kill
    # healthy nodes and break the bit-identical parity contract; under the
    # virtual clock the only death signal is transport EOF.
    heartbeat_s: float = 0.25
    suspect_after_s: float = 1.0
    dead_after_s: float = 5.0

    def resolved_seconds(self) -> Tuple[float, float, float]:
        """(preempt_gain_s, preempt_cooldown_s, refresh_every_s) with the
        deprecation shims applied: seconds-denominated fields win; tick
        fields are converted through tick_s and warn when overridden."""
        defaults = (("preempt_gain_ticks", 2.0, "preempt_gain_s"),
                    ("preempt_cooldown_ticks", 10.0, "preempt_cooldown_s"),
                    ("refresh_every", 8, "refresh_every_s"))
        for old, dflt, new in defaults:
            if getattr(self, old) != dflt and getattr(self, new) is None:
                warnings.warn(
                    f"GatewayConfig.{old} is deprecated (tick-denominated); "
                    f"set {new} in seconds instead", DeprecationWarning,
                    stacklevel=3)
        gain = (self.preempt_gain_s if self.preempt_gain_s is not None
                else self.preempt_gain_ticks * self.tick_s)
        cool = (self.preempt_cooldown_s
                if self.preempt_cooldown_s is not None
                else self.preempt_cooldown_ticks * self.tick_s)
        refresh = (self.refresh_every_s if self.refresh_every_s is not None
                   else self.refresh_every * self.tick_s)
        return float(gain), float(cool), float(refresh)


@dataclasses.dataclass
class _InFlight:
    stage: LiveStage
    node_id: int
    model: str
    req: Request
    r_need: float                     # reserved KV bytes (make_room target)
    submit_at: float                  # clock time the engine may see it
    submitted: bool = False


class ClusterGateway:
    """The LIVE-plane Substrate: pluggable clock, real engine execution."""

    def __init__(self, fleet: Sequence[NodeRuntime], rtt_s: np.ndarray,
                 predictor=None, policy: Union[str, SchedPolicy] = "maestro",
                 cfg: Optional[GatewayConfig] = None,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg or GatewayConfig()
        if self.cfg.node_backend not in ("inproc", "process", "socket"):
            raise ValueError(f"unknown node_backend "
                             f"{self.cfg.node_backend!r}")
        # clock plane: the event machinery (delayed RTT/T_act releases,
        # periodic cadences, run deadline) lives in the Clock — built
        # first so an invalid mode fails before any fleet state is touched
        self.clock = make_clock(self.cfg.clock, self.cfg.tick_s)
        # a fleet of worker handles implies a worker backend even when the
        # config was left at its default (the handle knows whether it is
        # process- or socket-connected); the reverse mismatch is a hard
        # error (an in-process runtime cannot be stepped remotely)
        is_worker_fleet = bool(fleet) and all(hasattr(n, "step_send")
                                              for n in fleet)
        if self.cfg.node_backend in ("process", "socket") \
                and not is_worker_fleet:
            raise ValueError(
                f"node_backend={self.cfg.node_backend!r} requires worker "
                f"NodeHandles — build the fleet with build_fleet(spec, "
                f"backend={self.cfg.node_backend!r}); 'process' and "
                f"'socket' fleets cannot be in-process runtimes")
        self.node_backend = (
            getattr(next(iter(fleet)), "backend", "process")
            if is_worker_fleet else self.cfg.node_backend)
        self.fleet: Dict[int, NodeRuntime] = {n.node_id: n for n in fleet}
        self.rtt_s = validate_rtt(rtt_s)
        # pristine copy for restore_link after fault-injected degradation
        self._nominal_rtt = self.rtt_s.copy()
        self.profiles = {name: p
                         for name, p in next(iter(self.fleet.values()))
                         .profiles.items()}
        self.telemetry = telemetry or Telemetry()
        (self.preempt_gain_s, self.preempt_cooldown_s,
         refresh_every_s) = self.cfg.resolved_seconds()
        self.policy = (make_policy(policy, predictor=predictor)
                       if isinstance(policy, str) else policy)

        self._refresh_cad = self.clock.cadence(refresh_every_s)
        self._headroom_cad = self.clock.cadence(
            self.cfg.headroom_sample_every * self.cfg.tick_s)
        self._deadline_hit: Optional[RunDeadlineExceeded] = None
        # wall-clock accounting: real busy seconds per node (in-process
        # backend; worker processes report their own step wall)
        self._node_busy_s: Dict[int, float] = {nid: 0.0 for nid in self.fleet}
        self._run_wall0: Optional[float] = None
        if self.clock.name == "wall" and self.node_backend != "inproc":
            # workers free-run: continuous stepping inside each child, the
            # gateway polls for finished requests instead of lock-stepping
            for node in self.fleet.values():
                node.set_continuous(True)

        # membership plane: the registry tracks liveness for every backend
        # (so register/retire/death events are uniformly visible), but the
        # timeout sweep + idle pings run ONLY under wall clock + worker
        # backends — heartbeats in virtual seconds would be meaningless and
        # extra pings would break the bit-identical parity contract. Under
        # the virtual clock death is detected by transport EOF alone.
        self.registry = FleetRegistry(
            HeartbeatConfig(interval_s=self.cfg.heartbeat_s,
                            suspect_after_s=self.cfg.suspect_after_s,
                            dead_after_s=self.cfg.dead_after_s),
            detector=StragglerDetector())
        for nid in self.fleet:
            self.registry.register(nid, self.clock.now())
        self._liveness_on = (self.clock.name == "wall"
                             and self.node_backend != "inproc")
        self._hb_cad = (self.clock.cadence(self.cfg.heartbeat_s)
                        if self._liveness_on else None)
        # piggybacked-heartbeat bookkeeping: a node whose reply counter
        # advanced since the last sweep was provably alive (every consumed
        # reply is a beat) — only silent nodes get an explicit ping
        self._last_traffic: Dict[int, int] = {
            nid: getattr(n, "ipc_calls", 0)
            for nid, n in self.fleet.items()}
        self._last_busy: Dict[int, float] = {nid: 0.0 for nid in self.fleet}
        self._last_sweep_t: Optional[float] = None
        self._requeued_stages = 0
        # dead/retired handles kept for end-of-run counter harvesting +
        # close(); their node ids have already left self.fleet
        self._gone_handles: List = []

        # workload state
        self.stage_by_id: Dict[int, LiveStage] = {}
        self.jobs: Dict[int, LiveJob] = {}
        self.pending_deps: Dict[int, int] = {}
        self.ready_t: Dict[int, float] = {}
        self.done: set = set()
        self.job_done_stages: Dict[int, int] = {}
        self.job_finish: Dict[int, float] = {}
        self.dropped: set = set()
        self.arrivals: List[Tuple[float, int]] = []   # (arrival_s, job_id)
        self.inflight: Dict[int, _InFlight] = {}      # stage_id -> record
        self.node_load: Dict[int, int] = {nid: 0 for nid in self.fleet}
        self.inflight_cap: Dict[int, int] = {
            nid: (self.cfg.max_inflight_per_node
                  or self.fleet[nid].max_slots)
            for nid in self.fleet}
        self.qd_ewma: Dict[int, float] = {nid: 0.0 for nid in self.fleet}
        # KV reserved by dispatched-but-not-yet-submitted stages: charged at
        # dispatch so admission cannot hand the same headroom to two stages
        # during the rtt + t_act transit window, released when the engine's
        # own accounting takes over at submit
        self.pending_resv: Dict[int, float] = {nid: 0.0 for nid in self.fleet}
        # largest prompt ANY node's engine window accepts (>=1 decode slot);
        # per-node windows can be smaller — the engine's typed
        # PromptTooLongError in _submit_inflight stays as the backstop
        self._max_prompt = max(n.s_max for n in self.fleet.values()) - 1
        self._truncated = 0
        self._rejects: Dict[int, int] = collections.defaultdict(int)
        self._views: Dict[int, SchedStage] = {}
        # prefix-affinity routing inputs: chained page digests of each
        # stage's prompt, computed lazily per stage and memoized (the page
        # geometry is fleet-uniform — every node shares one arena layout)
        self._page_tokens = next(iter(self.fleet.values())).page_tokens
        self._stage_digests: Dict[int, Tuple[str, ...]] = {}

        # the global queue: (priority, seq, stage_id) heap + live-id set;
        # priorities come from policy.priority and are refreshed on the
        # aging cadence (stale in between, exactly like the sim's heap)
        self._q: List[Tuple[float, int, int]] = []
        self._queued: set = set()
        self._qseq = 0
        self.policy.setup(self)

    # ----------------------------------------------------------------- views
    @property
    def now(self) -> float:
        return self.clock.now()

    @property
    def tick(self) -> int:
        """Tick counter of the virtual clock (legacy introspection); on the
        wall clock, the nominal tick index real time corresponds to."""
        if isinstance(self.clock, VirtualClock):
            return self.clock.tick
        return int(self.clock.now() / self.cfg.tick_s)

    @property
    def ctl(self):
        """The policy's MaestroController when it has one (calibration
        introspection for examples/benchmarks); None for baselines."""
        return getattr(self.policy, "ctl", None)

    def model_of(self, stage: LiveStage) -> str:
        return model_name(stage.obs, self.profiles)

    def rtt(self, stage: LiveStage, nid: int) -> float:
        src = stage.obs.src_cluster % self.rtt_s.shape[0]
        return float(self.rtt_s[src, self.fleet[nid].cluster_id])

    def view(self, stage: LiveStage) -> SchedStage:
        v = self._views.get(stage.stage_id)
        if v is None:
            job = self.jobs[stage.job_id]
            v = SchedStage(stage_id=stage.stage_id, job_id=stage.job_id,
                           model=self.model_of(stage),
                           interactive=stage.interactive,
                           prompt_len=stage.obs.prompt_len,
                           arrival_s=job.arrival_s,
                           deadline_s=job.deadline_s, obs=stage.obs)
            self._views[stage.stage_id] = v
        return v

    # --------------------------------------------------- Substrate protocol
    def node_ids(self) -> Sequence[int]:
        return sorted(self.fleet)

    def _reported_signal(self, nid: int) -> Optional[NodeSignal]:
        """Wall clock + free-running workers: the boundary-fresh NodeSignal
        the child piggybacked on its last poll reply (§III's periodic
        node->scheduler report). Routing/admission against this report
        costs no round trip — a synchronous query would block until the
        child's next engine-step boundary, stalling the dispatch loop.
        None outside that mode (or before the first poll), meaning: ask
        the node synchronously."""
        if self.clock.name == "wall" and self.node_backend != "inproc":
            return self.fleet[nid].last_signal()
        return None

    def signal(self, nid: int) -> NodeSignal:
        """Live NodeSignal with the gateway's clock-based queue-delay EWMA
        (the runtime's own queue statistic is engine-local, not seconds)."""
        sig = self._reported_signal(nid)
        sig = (dataclasses.replace(sig) if sig is not None
               else self.fleet[nid].signal())
        sig.queue_delay_s = self.qd_ewma[nid]
        return sig

    def load(self, nid: int) -> int:
        return self.node_load[nid]

    def can_admit(self, nid: int, r_need: float,
                  model: Optional[str] = None) -> bool:
        if self.node_load[nid] >= self.inflight_cap[nid]:
            return False
        sig = self._reported_signal(nid)
        if sig is not None:
            # signal-based admission: charge un-warm models their weight +
            # context against the REPORTED headroom. Conservative relative
            # to the node's own eviction-aware check (reclaimable-by-
            # degradation memory is not counted); the engine's waiting
            # queue + make_room at submit remain the ground-truth backstop.
            extra = 0.0
            if model is not None and model not in sig.warm_models:
                prof = self.profiles[model]
                extra = prof.weight_bytes + prof.ctx_bytes
            return (sig.headroom - self.pending_resv[nid]
                    >= r_need + extra)
        return self.fleet[nid].can_admit(
            r_need + self.pending_resv[nid], model)

    def t_act(self, nid: int, model: str) -> float:
        sig = self._reported_signal(nid)
        if sig is not None:
            if model in sig.warm_models:
                return sig.warm_models[model]
            # cold model on a free-running worker: estimate the host->device
            # transfer from the profile instead of a blocking round trip
            # (a sync query would stall dispatch until the child's next
            # engine-step boundary; routing only needs the ranking signal)
            prof = self.profiles[model]
            return prof.weight_bytes / prof.hw.host_link_bw
        return self.fleet[nid].t_act(model)

    def degradation_cost(self, nid: int, r_need: float) -> Optional[float]:
        sig = self._reported_signal(nid)
        if sig is not None and sig.headroom >= r_need:
            # no shortfall against the reported headroom: C_deg is 0 by
            # definition (NodeRuntime's own shortfall<=0 fast path) — skip
            # the blocking round trip. A genuine shortfall still asks the
            # node (it needs the engines' in-flight state for Alg. 2).
            return 0.0
        return self.fleet[nid].degradation_cost(r_need)

    def known_stages(self) -> List[SchedStage]:
        return []                     # stages arrive online

    def static_reservation(self, stage: SchedStage) -> float:
        prof = self.profiles[stage.model]
        return prof.r_kv(len(self.stage_by_id[stage.stage_id].tokens),
                         self.cfg.static_reserve_tokens)

    def t_exec_est(self, stage: SchedStage,
                   l_hat: Optional[float]) -> float:
        """Stage duration under the NOMINAL virtual execution model (prefill
        tick + one decode tick per predicted token, capped by the decode
        budget). Used by policies on BOTH clocks — wall-mode scheduling
        ranks by the same estimates, so decisions share one code path."""
        ls = self.stage_by_id[stage.stage_id]
        l_hat = ls.max_new if l_hat is None else min(l_hat, ls.max_new)
        return self.cfg.tick_s * (1.0 + l_hat)

    def true_remaining_s(self, stage: SchedStage) -> float:
        job = self.jobs[stage.job_id]
        return sum(self.cfg.tick_s * (1.0 + s.max_new) for s in job.stages
                   if s.stage_id not in self.done)

    def ready_since(self, stage_id: int) -> float:
        return self.ready_t.get(stage_id, float("inf"))

    def prefix_digests(self, stage: SchedStage) -> Sequence[str]:
        """Chained prefix-page digests of the stage's live prompt, for
        prefix-affinity routing (the same chain the node engines index)."""
        d = self._stage_digests.get(stage.stage_id)
        if d is None:
            ls = self.stage_by_id[stage.stage_id]
            d = tuple(page_digests(ls.tokens, self._page_tokens,
                                   stage.model))
            self._stage_digests[stage.stage_id] = d
        return d

    def job_remaining_v(self, stage: LiveStage) -> float:
        """Remaining nominal execution time of the stage's job, AFTER this
        stage — the Eq. 8 sample recorded into the WorkflowProfileStore."""
        job = self.jobs[stage.job_id]
        return sum(self.cfg.tick_s * (1.0 + s.max_new) for s in job.stages
                   if s.stage_id not in self.done
                   and s.stage_id != stage.stage_id)

    # -------------------------------------------------------- global queue
    def _q_push(self, stage: LiveStage, now: float) -> None:
        self._qseq += 1
        pri = self.policy.priority(self, self.view(stage), now)
        heapq.heappush(self._q, (pri, self._qseq, stage.stage_id))
        self._queued.add(stage.stage_id)

    def _q_peek(self, now: float) -> Optional[LiveStage]:
        while self._q:
            _, _, sid = self._q[0]
            if sid not in self._queued:
                heapq.heappop(self._q)     # stale entry
                continue
            return self.stage_by_id[sid]
        return None

    def _q_pop(self, now: float) -> Optional[LiveStage]:
        stage = self._q_peek(now)
        if stage is not None:
            heapq.heappop(self._q)
            self._queued.discard(stage.stage_id)
        return stage

    def _q_discard(self, stage_id: int) -> None:
        self._queued.discard(stage_id)

    def _q_refresh(self, now: float) -> None:
        """Recompute (aged) priorities — heap entries are stale otherwise."""
        live = list(self._queued)
        self._q.clear()
        self._queued.clear()
        for sid in live:
            self._q_push(self.stage_by_id[sid], now)

    # ------------------------------------------------------------- workload
    def submit_jobs(self, jobs: Sequence[LiveJob]) -> None:
        for j in jobs:
            self.jobs[j.job_id] = j
            self.job_done_stages.setdefault(j.job_id, 0)
            if j.deadline_s <= 0.0:
                j.deadline_s = self._deadline(j)
            self.arrivals.append((j.arrival_s, j.job_id))
            for s in j.stages:
                self.stage_by_id[s.stage_id] = s
                self.pending_deps[s.stage_id] = len(s.deps)
        self.arrivals.sort()

    def _deadline(self, job: LiveJob) -> float:
        """SLO profiling against the nominal virtual execution model:
        critical-path time with everything warm, scaled by slo_factor.
        (Wall-clock runs keep these nominal deadlines — batch SLO rows are
        machine-dependent there; see docs/BENCHMARKS.md.)"""
        finish: Dict[int, float] = {}
        for s in job.stages:
            start = max((finish[d] for d in s.deps), default=0.0)
            finish[s.stage_id] = start + self.cfg.tick_s * (2.0 + s.max_new)
        return self.cfg.slo_factor * max(finish.values())

    # ------------------------------------------------------------ event loop
    def _auto_deadline_s(self, jobs: Sequence[LiveJob]) -> float:
        """Workload-derived safety cap (the legacy ``max_ticks`` heuristic,
        now expressed in seconds and enforced by the Clock with a typed
        outcome instead of silent truncation)."""
        n_stage_ticks = sum(s.max_new + 6 for j in jobs for s in j.stages)
        return (40 * n_stage_ticks + 4000) * self.cfg.tick_s

    def run(self, jobs: Sequence[LiveJob],
            max_ticks: Optional[int] = None,
            max_run_s: Optional[float] = None,
            fault_plan=None) -> GatewayMetrics:
        """Serve ``jobs`` to completion or until the run deadline.

        The deadline comes from (first match wins) the deprecated
        ``max_ticks`` argument (virtual ticks), the ``max_run_s`` argument,
        ``GatewayConfig.max_run_s``, or — virtual clock only — the
        workload-derived safety cap. A deadline that fires is reported as a
        typed ``RunDeadlineExceeded`` in the returned metrics.

        ``fault_plan`` (a ``repro.serving.faultplan.FaultPlan``, duck-typed
        via its ``arm``) schedules mid-run events — worker kills, link
        degradation, replacement nodes — on this gateway's clock; arming
        happens after the clock restart so event times are run-relative."""
        self.submit_jobs(jobs)
        self._run_wall0 = time.perf_counter()
        # serving time starts NOW: pre-run work (e.g. warmup) is not billed
        # to the measured window (no-op on the virtual clock)
        self.clock.restart()
        if fault_plan is not None:
            fault_plan.arm(self)
        if max_run_s is None:
            max_run_s = self.cfg.max_run_s
        if max_ticks is not None:
            if isinstance(self.clock, VirtualClock):
                self.clock.set_deadline_ticks(max_ticks)  # exact legacy cap
            else:
                self.clock.set_deadline(max_ticks * self.cfg.tick_s)
        elif max_run_s is not None:
            self.clock.set_deadline(max_run_s)
        elif isinstance(self.clock, VirtualClock):
            self.clock.set_deadline(self._auto_deadline_s(jobs))
        # wall clock with no explicit cap: unbounded (machine speed unknown)
        while self._unfinished() and not self.clock.expired():
            self.step()
        if self._unfinished() and self.clock.expired():
            self._deadline_hit = RunDeadlineExceeded(
                max_run_s=float(self.clock.deadline_s),
                elapsed_s=self.clock.now(),
                unfinished_jobs=sum(1 for j in self.jobs
                                    if j not in self.job_finish
                                    and j not in self.dropped))
        return self.metrics()

    def _unfinished(self) -> bool:
        return any(j not in self.job_finish and j not in self.dropped
                   for j in self.jobs)

    def metrics(self) -> GatewayMetrics:
        m = self.telemetry.summary(
            self.policy.name, list(self.jobs.values()), self.job_finish,
            self.cfg.interactive_budget_s, self.now)
        # physical paged-KV arena: worst-node overcommit + fleet peaks —
        # kv_stats() is one round trip per node on the process backend
        stats = [n.kv_stats() for n in self.fleet.values()]
        m.kv_overcommit_ratio = max(
            (s["kv_overcommit_ratio"] for s in stats if s["n_engines"]),
            default=0.0)
        m.arena_peak_pages = sum(s["arena_peak_pages"] for s in stats)
        m.arena_utilization = max(
            (s["arena_utilization"] for s in stats), default=0.0)
        # prefix-cache plane: fleet-summed index counters (plus the arena's
        # alias/COW totals) — empty keys stay absent when no node enabled it
        pkeys = sorted({k for s in stats for k in s
                        if k.startswith("prefix_")})
        if pkeys:
            m.prefix_stats = {k: float(sum(s.get(k, 0) for s in stats))
                              for k in pkeys}
            for k in ("pages_aliased", "cow_copies"):
                m.prefix_stats[k] = float(sum(s.get(k, 0) for s in stats))
        # engine iteration-scheduler counters, summed fleet-wide (older
        # kv_stats snapshots may lack them — remote workers predate the keys)
        for k in ("engine_prefill_tokens", "engine_decode_tokens",
                  "engine_prefill_compiles", "engine_fused_steps",
                  "engine_steps", "engine_horizon_steps",
                  "engine_decode_syncs"):
            setattr(m, k, int(sum(s.get(k, 0) for s in stats)))
        # decode-horizon headline: host round-trips per emitted decode token
        m.host_syncs_per_token = (m.engine_decode_syncs
                                  / max(m.engine_decode_tokens, 1))
        m.truncated_stages = self._truncated
        m.node_backend = self.node_backend
        m.clock = self.clock.name
        if self._deadline_hit is not None:
            m.run_outcome = "deadline_exceeded"
            m.run_deadline = self._deadline_hit
        if self.node_backend != "inproc":
            # dead/retired handles first so a replacement that re-used a
            # node id overwrites them with the live handle's counters
            for node in self._gone_handles:
                self.telemetry.record_worker(node.node_id,
                                             node.worker_stats())
            for nid, node in self.fleet.items():
                self.telemetry.record_worker(nid, node.worker_stats())
            m.worker_stats = dict(self.telemetry.worker_stats)
            m.ipc_calls = sum(int(w["ipc_calls"])
                              for w in m.worker_stats.values())
            m.ipc_wall_s = sum(w["ipc_wall_s"]
                               for w in m.worker_stats.values())
            m.worker_step_wall_s = sum(w["worker_step_wall_s"]
                                       for w in m.worker_stats.values())
            m.heartbeat_misses = sum(
                int(w.get("heartbeat_misses", 0))
                for w in m.worker_stats.values())
            # socket transport overhead (zero on the pipe backends)
            m.rpc_bytes_sent = sum(int(w.get("bytes_sent", 0))
                                   for w in m.worker_stats.values())
            m.rpc_bytes_recv = sum(int(w.get("bytes_recv", 0))
                                   for w in m.worker_stats.values())
        # membership plane: deaths/evacuations and end-of-run liveness.
        # Identical across backends under the virtual clock (no deaths, all
        # healthy) so the parity contract holds; straggler flags are wall-
        # only because the observations are real seconds.
        m.node_deaths = len(self.telemetry.node_deaths)
        m.death_events = list(self.telemetry.node_deaths)
        m.requeued_stages = self._requeued_stages
        m.liveness = self.registry.states()
        if self.clock.name == "wall":
            m.straggler_nodes = self.registry.stragglers()
            # wall-only telemetry (left zero/empty on the virtual clock so
            # virtual metrics stay bit-identical across backends):
            # makespan in real seconds, per-node busy fractions and the
            # fleet overlap factor (sum of busy seconds / makespan; > 1
            # means engine compute genuinely overlapped across nodes)
            m.wall_makespan_s = m.makespan_s
            busy = (dict(self._node_busy_s)
                    if self.node_backend == "inproc" else
                    {nid: node.worker_stats()["worker_step_wall_s"]
                     for nid, node in self.fleet.items()})
            span = max(m.makespan_s, 1e-9)
            m.node_busy_frac = {nid: b / span for nid, b in busy.items()}
            m.overlap_factor = sum(busy.values()) / span
        return m

    def close(self) -> None:
        """Shut worker processes down (no-op for the in-process backend),
        including handles already dead or retired mid-run."""
        close_fleet(list(self.fleet.values()) + self._gone_handles)

    def warmup(self) -> None:
        """Pre-activate every model on every node by running one tiny
        request through each engine (prefill + decode), so weight transfer,
        JIT compilation and first-touch allocation happen BEFORE the
        measured serving window — the standard deployment warmup. On the
        worker-process fleet children warm up in parallel. Not called by
        default: virtual-clock baselines and tests measure cold fleets;
        the wall-clock benchmark calls it so makespan compares steady-state
        serving rather than per-process compile time."""
        for nid, node in self.fleet.items():
            for k, model in enumerate(sorted(self.profiles)):
                node.submit(model, Request(req_id=-(nid * 64 + k + 1),
                                           tokens=[1, 2, 3], max_new=2))
        free_running = (self.clock.name == "wall"
                        and self.node_backend != "inproc")
        for _ in range(512):                    # bounded drain
            if not any(n.has_work() for n in self.fleet.values()):
                break
            if free_running:
                # children already free-run: just drain their buffers
                for n in self.fleet.values():
                    n.poll_finished()
                time.sleep(0.005)
            elif self.node_backend != "inproc":
                for n in self.fleet.values():
                    n.step_send()
                for n in self.fleet.values():
                    n.step_recv()
            else:
                for n in self.fleet.values():
                    n.step()                    # warmup output discarded

    def step(self) -> None:
        now = self.clock.now()
        # 1) arrivals: source stages of newly arrived jobs become ready
        while self.arrivals and self.arrivals[0][0] <= now:
            _, jid = self.arrivals.pop(0)
            for s in self.jobs[jid].stages:
                if not s.deps:
                    self._mark_ready(s, now)
        # 2) membership sweep (wall clock + worker backends only): fold
        # piggybacked heartbeats, ping silent nodes, age the liveness state
        # machine, evacuate timeouts
        if self._liveness_on and self._hb_cad.due():
            self._membership_sweep(now)
        # 3) aging refresh of the global queue (clock-owned cadence)
        if self._refresh_cad.due():
            self._q_refresh(now)
        # 4) global-queue dispatch (routing + admission + preemption); a
        # worker dying mid-decision surfaces typed and is evacuated here
        try:
            self._dispatch(now)
        except WorkerDied as e:
            self._on_node_death(e.node_id, now, cause=str(e))
        # 5) transit releases: stages whose rtt + activation delay elapsed
        # (scheduled as clock events at dispatch) hit their engines
        self._fire_releases(now)
        # 6) engine progress: lock-step under the virtual clock, polling of
        # free-running workers / direct stepping under the wall clock
        did_work = self._collect_finished(now)
        # 7) telemetry sampling (reported signals when workers free-run —
        # an accountant round trip would block on an engine-step boundary)
        if self._headroom_cad.due():
            for nid, node in list(self.fleet.items()):
                try:
                    sig = self._reported_signal(nid)
                    self.telemetry.sample_headroom(
                        nid, sig.headroom if sig is not None
                        else node.acc.headroom)
                except WorkerDied as e:
                    self._on_node_death(e.node_id, now, cause=str(e))
        # 8) advance time: one tick (virtual) or sleep until the next
        # wake-up (wall; skipped when engines did real work this pass)
        self.clock.advance(None if did_work else self._next_wake(now))

    def _collect_finished(self, now: float) -> bool:
        """Drive engine progress and drain finished requests; returns True
        when real engine work happened this pass (wall-clock pacing)."""
        if self.clock.name != "wall":
            # virtual: one lock-step iteration of every busy engine. Process
            # backend: broadcast the step to all workers first so node
            # iterations run concurrently, then collect replies in node
            # order — same per-node event order as the cooperative
            # in-process loop, so the virtual-clock outcome is identical
            # (tests/test_worker.py parity)
            if self.node_backend != "inproc":
                for node in list(self.fleet.values()):
                    try:
                        node.step_send()
                    except WorkerDied as e:
                        self._on_node_death(e.node_id, now, cause=str(e))
            for nid, node in list(self.fleet.items()):
                if nid not in self.fleet:      # died earlier this pass
                    continue
                try:
                    out = (node.step_recv()
                           if self.node_backend != "inproc"
                           else node.step())
                except WorkerDied as e:
                    self._on_node_death(e.node_id, now, cause=str(e))
                    continue
                self._drain(out, now)
            return True
        if self.node_backend != "inproc":
            # workers free-run with one poll outstanding per busy node; the
            # gateway folds in whatever replies are already in the pipe
            # (a child answers at its next engine-step boundary), then
            # re-arms — the dispatch loop NEVER blocks on worker compute,
            # so finished stages turn into new dispatches within ~wall_poll_s
            for nid, node in list(self.fleet.items()):
                try:
                    out = node.drain_ready()
                    if out:
                        self._drain(out, self.clock.now())
                    for rid in node.take_submit_errors():
                        # async submit rejected (typed prompt-too-long): the
                        # stage finishes truncated, same as the sync path
                        rec = self.inflight.get(rid)
                        if rec is not None:
                            rec.req.truncated = True
                            self._truncated += 1
                            self._on_finish(rec.req, self.clock.now())
                    node.poll_send()
                except WorkerDied as e:
                    self._on_node_death(e.node_id, self.clock.now(),
                                        cause=str(e))
            return False      # polling is not compute: let advance() pace
        # wall + in-process: the gateway itself steps busy engines, one
        # node after another — real elapsed time, but serialized in this
        # process (the measured contrast to the free-running worker fleet)
        stepped = False
        for nid, node in self.fleet.items():
            if node.has_work():
                t0 = time.perf_counter()
                out = node.step()
                self._node_busy_s[nid] += time.perf_counter() - t0
                stepped = True
                self._drain(out, self.clock.now())
        return stepped

    def _drain(self, out: Dict[str, List[Request]], now: float) -> None:
        for model, reqs in out.items():
            for req in reqs:
                self._on_finish(req, now)

    def _next_wake(self, now: float) -> float:
        """Earliest clock time anything can change (wall-clock sleep hint):
        the next arrival, the next transit release, or a short poll
        interval while work is queued or in flight."""
        cands = []
        if self.arrivals:
            cands.append(self.arrivals[0][0])
        nxt = self.clock.peek_next()
        if nxt is not None:
            cands.append(nxt)
        if self.inflight or self._queued:
            cands.append(now + self.cfg.wall_poll_s)
        if not cands:
            return now + self.cfg.wall_poll_s
        return min(cands)

    # -------------------------------------------------------------- phases
    def _mark_ready(self, stage: LiveStage, now: float) -> None:
        if stage.job_id in self.dropped:
            return
        self.ready_t[stage.stage_id] = now
        ev = self.telemetry.event(stage.stage_id, stage.job_id,
                                  stage.interactive)
        ev.ready_t = now
        ev.model = self.model_of(stage)
        self._q_push(stage, now)

    def _dispatch(self, now: float) -> None:
        while self._queued:
            stage = self._q_peek(now)
            if stage is None:
                break
            if stage.job_id in self.dropped or stage.stage_id in self.done:
                self._q_pop(now)
                continue
            if len(stage.tokens) > self._max_prompt:
                # no engine window in the fleet can hold this prompt: finish
                # it truncated HERE, before it costs a dispatch, transit
                # delay, cold start or make_room eviction it can never use
                self._q_pop(now)
                self._truncated += 1
                req = Request(req_id=stage.stage_id,
                              tokens=list(stage.tokens),
                              max_new=stage.max_new, truncated=True)
                self._complete(stage, self.model_of(stage), req, now)
                continue
            view = self.view(stage)
            r_need = self.policy.reservation(self, view)
            nid = self.policy.route(self, view, r_need)
            if nid is None:
                # memory infeasibility (a node had a free slot yet could not
                # admit) is an ADMISSION rejection; all-slots-busy is plain
                # queueing and neither counted nor held against the job
                slots_free = any(self.node_load[n] < self.inflight_cap[n]
                                 for n in self.fleet)
                if slots_free:
                    self.telemetry.admission_rejections += 1
                    self.telemetry.event(stage.stage_id, stage.job_id,
                                         stage.interactive).rejections += 1
                    self._rejects[stage.stage_id] += 1
                if (self.policy.requeue_at_boundary and stage.interactive
                        and self._try_preempt(stage, now)):
                    continue                   # retry the head post-eviction
                if self._rejects[stage.stage_id] > self.cfg.reject_limit:
                    self._drop_job(stage.job_id, now)
                    continue
                break                          # head-of-line block
            self._q_pop(now)
            try:
                self._dispatch_to(stage, nid, r_need, now)
            except WorkerDied as e:
                # the chosen node died between routing and dispatch: the
                # stage is already popped, so put it straight back in the
                # queue (still not-yet-dispatched) and evacuate the node
                self._q_push(stage, now)
                self._on_node_death(e.node_id, now, cause=str(e))

    def _dispatch_to(self, stage: LiveStage, nid: int, r_need: float,
                     now: float) -> None:
        view = self.view(stage)
        model = view.model
        rtt = self.rtt(stage, nid)
        # through the Substrate method, NOT the node: under the wall clock
        # with free-running workers it answers from the reported signal (a
        # direct node query would block until an engine-step boundary)
        t_act = self.t_act(nid, model)
        if t_act > COLD_START_THRESHOLD_S:
            self.telemetry.cold_starts += 1
        l_hat = self.policy.predicted_len(self, view)
        req = Request(req_id=stage.stage_id, tokens=list(stage.tokens),
                      max_new=stage.max_new,
                      pred_len=(None if l_hat is None
                                else float(min(l_hat, stage.max_new))))
        rec = _InFlight(
            stage=stage, node_id=nid, model=model, req=req, r_need=r_need,
            submit_at=now + rtt + t_act)
        self.inflight[stage.stage_id] = rec
        # RTT + activation transit as a timed event release on the clock
        self.clock.call_at(rec.submit_at, rec)
        self.node_load[nid] += 1
        self.pending_resv[nid] += r_need
        wait = max(0.0, now - self.ready_t.get(stage.stage_id, now))
        self.qd_ewma[nid] = 0.8 * self.qd_ewma[nid] + 0.2 * (wait + t_act)
        ev = self.telemetry.event(stage.stage_id, stage.job_id,
                                  stage.interactive)
        ev.node_id, ev.dispatch_t = nid, now
        ev.rtt_s, ev.t_act_s = rtt, t_act

    def _fire_releases(self, now: float) -> None:
        """Submit every stage whose transit event released. Stale events
        (the stage was preempted or re-dispatched while in transit, so a
        different record — or none — is in flight) are dropped. Callable
        payloads (fault-plan events armed via ``clock.call_at``) run here,
        at the same clock boundary as transit releases, so injected faults
        land at deterministic virtual times."""
        for rec in self.clock.pop_due():
            if callable(rec):
                rec(now)
                continue
            if self.inflight.get(rec.stage.stage_id) is not rec \
                    or rec.submitted:
                continue
            self._submit_inflight(rec, now)

    def _submit_inflight(self, rec: _InFlight, now: float) -> None:
        try:
            self._submit_inflight_inner(rec, now)
        except WorkerDied as e:
            # node died under the submit: evacuation requeues this record
            # (and every sibling in flight there) as not-yet-dispatched
            self._on_node_death(e.node_id, now, cause=str(e))

    def _submit_inflight_inner(self, rec: _InFlight, now: float) -> None:
        node = self.fleet[rec.node_id]
        sig = self._reported_signal(rec.node_id)
        if sig is not None and sig.headroom >= rec.r_need:
            pass        # reported headroom covers it: no accountant query
        elif not node.acc.can_admit(rec.r_need):
            # Alg. 2 cheap prefix (levels 1-2) executed live: sleep idle
            # engines / drop warm contexts so the reservation fits
            node.make_room(rec.r_need)
        t0 = time.perf_counter()
        rec.submitted = True
        self.pending_resv[rec.node_id] -= rec.r_need
        if self.clock.name == "wall" and self.node_backend != "inproc":
            # free-running fleet: fire-and-forget — the ack (or typed
            # prompt-too-long, surfaced via take_submit_errors on the next
            # drain) would otherwise block the dispatch loop until the
            # child's engine-step boundary
            node.submit_send(rec.model, rec.req)
            ev = self.telemetry.event(rec.stage.stage_id, rec.stage.job_id,
                                      rec.stage.interactive)
            ev.start_t = now          # wall_act_s unknown on the async path
            return
        try:
            node.submit(rec.model, rec.req)   # real activation on demand
        except PromptTooLongError:
            # typed rejection instead of silent KV overflow: the stage
            # finishes truncated (empty output) and its job continues
            rec.req.truncated = True
            self._truncated += 1
            self._on_finish(rec.req, now)
            return
        ev = self.telemetry.event(rec.stage.stage_id, rec.stage.job_id,
                                  rec.stage.interactive)
        ev.start_t = now
        ev.wall_act_s = time.perf_counter() - t0

    def _on_finish(self, req: Request, now: float) -> None:
        rec = self.inflight.pop(req.req_id, None)
        if rec is None:
            return
        self.node_load[rec.node_id] -= 1
        self._complete(rec.stage, rec.model, req, now)

    def _complete(self, stage: LiveStage, model: str, req: Request,
                  now: float) -> None:
        self.done.add(stage.stage_id)
        self._rejects.pop(stage.stage_id, None)
        ev = self.telemetry.event(stage.stage_id, stage.job_id,
                                  stage.interactive)
        # telemetry's finished sentinel is finish_t > 0; dispatch-time
        # truncation can legitimately land at exactly t=0, so clamp
        ev.finish_t, ev.out_len = max(now, 1e-9), len(req.out)
        ev.prompt_tokens = len(req.tokens)
        ev.prefill_avoided = int(getattr(req, "prefill_avoided", 0))
        ev.ttft_s = float(getattr(req, "ttft_s", 0.0))
        # Calibrate on the SAME basis the prediction used (the uncapped
        # trace-scale lengths): the realized output, mapped back through the
        # live decode budget, against L_hat. Comparing live capped bytes to
        # the uncapped R_kv_hat would make the error identically zero and
        # pin rho to its floor.
        if not req.truncated:
            # truncated stages never ran to their true length — feeding
            # their (near-zero) realized KV into calibration would record a
            # phantom maximal overprediction and skew rho for real stages
            prof = self.profiles[model]
            nominal = stage.nominal_len or stage.max_new
            actual_len = nominal * len(req.out) / max(stage.max_new, 1)
            actual_kv = prof.r_kv(stage.obs.prompt_len, actual_len)
            self.policy.on_finish(self, self.view(stage), actual_kv,
                                  self.job_remaining_v(stage))
        job = self.jobs[stage.job_id]
        self.job_done_stages[stage.job_id] += 1
        if self.job_done_stages[stage.job_id] == len(job.stages):
            self.job_finish[stage.job_id] = now
        # successor re-queueing: every dependent whose deps are all done
        # re-enters the GLOBAL queue and contends under the policy's order
        for st in job.stages:
            if stage.stage_id in st.deps:
                self.pending_deps[st.stage_id] -= 1
                if self.pending_deps[st.stage_id] == 0:
                    self._mark_ready(st, now)

    # ---------------------------------------------------- membership plane
    def _membership_sweep(self, now: float) -> None:
        """One heartbeat pass (wall clock + worker backends only): reap
        visibly dead processes, fold piggybacked heartbeats (any reply
        consumed since the last sweep proves the worker alive), ping nodes
        that were silent, feed step-wall deltas to the straggler detector,
        and age the liveness state machine.

        Stall amnesty: if the GATEWAY itself paused longer than a sweep
        period (a replacement worker booting inside a fault-plan event, a
        long jit compile, a GC-style hiccup), worker silence over that gap
        proves nothing — the gateway wasn't listening. Nodes that still
        look alive at the transport level get a free beat before aging, so
        a local pause never wipes a healthy fleet."""
        stalled = (self._last_sweep_t is not None
                   and now - self._last_sweep_t
                   > max(self.cfg.suspect_after_s,
                         2.0 * self.cfg.heartbeat_s))
        self._last_sweep_t = now
        for nid, node in list(self.fleet.items()):
            proc = getattr(node, "proc", None)
            if proc is not None and not proc.is_alive():
                self._on_node_death(
                    nid, now,
                    cause=f"process exited (exitcode={proc.exitcode})")
                continue
            calls = getattr(node, "ipc_calls", 0)
            if calls > self._last_traffic.get(nid, 0):
                self.registry.beat(nid, now)   # replies ARE heartbeats
            elif stalled:
                self.registry.beat(nid, now)   # our pause, not its silence
            elif hasattr(node, "ping_send"):
                try:
                    node.ping_send()           # idle-period probe
                except WorkerDied as e:
                    self._on_node_death(e.node_id, now, cause=str(e))
                    continue
            self._last_traffic[nid] = calls
            busy = (node.worker_stats()["worker_step_wall_s"]
                    if hasattr(node, "worker_stats")
                    else self._node_busy_s.get(nid, 0.0))
            delta = busy - self._last_busy.get(nid, 0.0)
            if delta > 0:
                self.registry.observe_step(nid, delta)
            self._last_busy[nid] = busy
        for nid in self.registry.update(now):
            if nid in self.fleet:
                self._on_node_death(nid, now, cause="heartbeat timeout")

    def _evacuate_node(self, nid: int, now: float) -> List[int]:
        """Pull every in-flight stage off node ``nid`` and put it back in
        the ready queue as not-yet-dispatched: the aborted attempt's wait
        is banked (like preemption), per-node reservations/prefix affinity
        are written off with the node, and pending transit releases go
        stale (they are dropped by the `is rec` check in _fire_releases).
        Returns the evacuated stage ids."""
        requeued: List[int] = []
        for sid, rec in list(self.inflight.items()):
            if rec.node_id != nid:
                continue
            del self.inflight[sid]
            ev = self.telemetry.event(sid, rec.stage.job_id,
                                      rec.stage.interactive)
            ev.worker_deaths += 1
            ev.prior_wait_s += (max(0.0, ev.dispatch_t - ev.ready_t)
                                + ev.rtt_s + ev.t_act_s)
            ev.rtt_s = ev.t_act_s = 0.0
            requeued.append(sid)
            self._mark_ready(rec.stage, now)
        self._requeued_stages += len(requeued)
        for d in (self.node_load, self.inflight_cap, self.qd_ewma,
                  self.pending_resv, self._node_busy_s,
                  self._last_traffic, self._last_busy):
            d.pop(nid, None)
        return requeued

    def _on_node_death(self, nid: int, now: float,
                       cause: str = "transport failure") -> None:
        """A worker died (transport EOF, dead process, heartbeat timeout):
        remove it from the serving fleet, evacuate its in-flight stages
        back to the ready queue, and surface a typed NodeDeathEvent. The
        survivors keep serving; losing the LAST node is fatal (nothing
        could ever finish and the loop would spin forever)."""
        node = self.fleet.pop(nid, None)
        if node is None:
            return                         # already evacuated this pass
        self._gone_handles.append(node)
        self.registry.mark_dead(nid, now, cause=cause)
        requeued = self._evacuate_node(nid, now)
        self.telemetry.node_death(NodeDeathEvent(
            node_id=nid, t=now, cause=cause,
            requeued_stages=tuple(requeued)))
        close_fleet([node])                # reap the corpse, best-effort
        if not self.fleet:
            raise RuntimeError(
                f"node {nid} died ({cause}) and no nodes remain in the "
                f"fleet — cannot make progress")
        self._max_prompt = max(n.s_max for n in self.fleet.values()) - 1

    def register_node(self, node) -> int:
        """Mid-run elasticity: admit a booted node (in-process
        ``NodeRuntime`` or worker handle matching the fleet's backend) to
        the serving fleet. A dead node's id may be reused — that is the
        reconnect path: a replacement worker joining under the same id."""
        if hasattr(node, "wait_ready"):
            node.wait_ready()
        nid = node.node_id
        if nid in self.fleet:
            raise ValueError(f"node {nid} is already in the fleet")
        now = self.clock.now()
        self.fleet[nid] = node
        self.node_load[nid] = 0
        self.pending_resv[nid] = 0.0
        self.qd_ewma[nid] = 0.0
        self.inflight_cap[nid] = (self.cfg.max_inflight_per_node
                                  or node.max_slots)
        self._node_busy_s[nid] = 0.0
        self._last_traffic[nid] = getattr(node, "ipc_calls", 0)
        self._last_busy[nid] = 0.0
        self._max_prompt = max(self._max_prompt, node.s_max - 1)
        self.registry.register(nid, now)
        if (self.clock.name == "wall" and self.node_backend != "inproc"
                and hasattr(node, "set_continuous")):
            node.set_continuous(True)
        return nid

    def degrade_link(self, src_cluster: int, dst_cluster: int,
                     factor: float) -> None:
        """Fault injection: inflate the RTT of one cross-cluster link by
        ``factor`` (both directions — links fail symmetrically). Stages
        already in transit keep their old release times; everything
        dispatched after this sees the degraded link."""
        if factor <= 0:
            raise ValueError(f"factor must be > 0, got {factor}")
        s = src_cluster % self.rtt_s.shape[0]
        d = dst_cluster % self.rtt_s.shape[0]
        self.rtt_s[s, d] = self._nominal_rtt[s, d] * factor
        self.rtt_s[d, s] = self._nominal_rtt[d, s] * factor

    def restore_link(self, src_cluster: int, dst_cluster: int) -> None:
        """Undo ``degrade_link``: the link returns to its nominal RTT."""
        s = src_cluster % self.rtt_s.shape[0]
        d = dst_cluster % self.rtt_s.shape[0]
        self.rtt_s[s, d] = self._nominal_rtt[s, d]
        self.rtt_s[d, s] = self._nominal_rtt[d, s]

    def retire_node(self, nid: int) -> List[int]:
        """Mid-run elasticity: gracefully drain a node. Its in-flight
        stages re-enter the ready queue as not-yet-dispatched (same
        evacuation as death, without the death event) and the worker shuts
        down. Returns the requeued stage ids."""
        node = self.fleet.pop(nid, None)
        if node is None:
            raise KeyError(f"node {nid} is not in the fleet")
        if len(self.fleet) == 0:
            self.fleet[nid] = node
            raise ValueError(f"cannot retire node {nid}: it is the last "
                             f"node in the fleet")
        now = self.clock.now()
        self._gone_handles.append(node)
        requeued = self._evacuate_node(nid, now)
        self.registry.retire(nid, now)
        close_fleet([node])
        self._max_prompt = max(n.s_max for n in self.fleet.values()) - 1
        return requeued

    # ---------------------------------------------------------- preemption
    def _decode_progress(self, rec: _InFlight) -> int:
        """Tokens the in-flight stage has produced so far. In-process the
        engine mutates the gateway's own Request; a worker process mutates a
        pickled copy, so the handle's last-step progress snapshot stands in
        — both observe the same engine-step boundary on the virtual clock."""
        if self.node_backend != "inproc" and rec.submitted:
            return self.fleet[rec.node_id].out_len(rec.req.req_id)
        return len(rec.req.out)

    def _try_preempt(self, stage: LiveStage, now: float) -> bool:
        """Boundary preemption: evict a batch stage between engine steps so
        an infeasible interactive head can place. The policy decides
        (hysteresis + cooldown); the victim restarts from its prompt."""
        cand = self.view(stage)
        victims = sorted(
            (r for r in self.inflight.values() if not r.stage.interactive),
            key=lambda r: -(r.stage.max_new - self._decode_progress(r)))
        for rec in victims:
            remaining_v = self.cfg.tick_s * max(
                1.0, 1.0 + rec.stage.max_new - self._decode_progress(rec))
            if not self.policy.should_preempt(self, self.view(rec.stage),
                                              remaining_v, cand, now):
                continue
            if rec.submitted:
                if self.fleet[rec.node_id].preempt(rec.model,
                                                   rec.req.req_id) is None:
                    continue   # finished this very tick; nothing to evict
            else:
                self.pending_resv[rec.node_id] -= rec.r_need
            self.inflight.pop(rec.stage.stage_id, None)
            self.node_load[rec.node_id] -= 1
            self.telemetry.preemptions += 1
            ev = self.telemetry.event(rec.stage.stage_id, rec.stage.job_id,
                                      False)
            ev.preemptions += 1
            # bank the aborted attempt's wait before _mark_ready resets it
            ev.prior_wait_s += (max(0.0, ev.dispatch_t - ev.ready_t)
                                + ev.rtt_s + ev.t_act_s)
            ev.rtt_s = ev.t_act_s = 0.0
            self._mark_ready(rec.stage, now)   # requeue from scratch
            return True
        return False

    def _drop_job(self, job_id: int, now: float) -> None:
        """Admission gave up on this job (reject_limit exceeded): withdraw
        its queued stages so the gateway keeps serving everyone else."""
        self.dropped.add(job_id)
        self.telemetry.dropped_jobs += 1
        for s in self.jobs[job_id].stages:
            if s.stage_id not in self.done:
                self._q_discard(s.stage_id)
                # also clear the readiness bookkeeping: a dropped job's
                # stages must not linger as orphan ids in ready_since (the
                # aging input policies read) or in the reject counters
                self.ready_t.pop(s.stage_id, None)
                self._rejects.pop(s.stage_id, None)
