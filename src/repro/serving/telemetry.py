"""Gateway telemetry: per-stage timing records, KV-headroom samples and
SLO/latency aggregation for the LIVE serving plane.

Times are in the gateway's CLOCK (``GatewayMetrics.clock`` records which):
deterministic step-driven virtual seconds under the default virtual clock,
real elapsed seconds under the wall clock — so wall-clock rows report queue
delay and SLO attainment against real time. ``wall_act_s`` always records
the real measured activation cost of the underlying ``NodeRuntime``
(host->device transfer + engine construction) regardless of clock.
The summary mirrors ``repro.sim.simulator.SimResult`` so the live plane and
the trace-driven simulator report the same policy-comparison columns.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.clock import RunDeadlineExceeded


@dataclasses.dataclass(frozen=True)
class NodeDeathEvent:
    """One worker death as the membership plane recorded it: when, why
    (transport EOF / heartbeat timeout / dead process reaped), and which
    in-flight stages were evacuated back to the ready queue."""
    node_id: int
    t: float
    cause: str
    requeued_stages: Tuple[int, ...] = ()


@dataclasses.dataclass
class StageEvent:
    """Lifecycle of one workflow stage through the gateway."""
    stage_id: int
    job_id: int
    interactive: bool
    model: str = ""
    node_id: int = -1
    ready_t: float = 0.0          # deps satisfied, entered the global queue
    dispatch_t: float = 0.0       # popped + routed by the policy
    start_t: float = 0.0          # submitted to the node engine (post rtt+act)
    finish_t: float = 0.0         # engine emitted the final token
    rtt_s: float = 0.0
    t_act_s: float = 0.0          # virtual activation latency (residency est.)
    wall_act_s: float = 0.0       # measured wall-clock activation
    out_len: int = 0
    prompt_tokens: int = 0        # live prompt length the engine prefetched
    prefill_avoided: int = 0      # prompt tokens served from the prefix cache
    ttft_s: float = 0.0           # engine-measured wall submit -> first token
                                  # (0.0 when the engine didn't stamp one)
    preemptions: int = 0          # times this stage was evicted + requeued
    rejections: int = 0           # routing/admission failures observed
    prior_wait_s: float = 0.0     # wait accrued by attempts aborted by
                                  # preemption (so eviction can't hide delay)
    worker_deaths: int = 0        # times this stage's node died under it
                                  # (stage re-entered the ready queue)

    @property
    def queue_delay_s(self) -> float:
        """Stage wait as the sim accounts it: queueing + network + cold start,
        summed over every dispatch attempt."""
        return (self.prior_wait_s + max(0.0, self.dispatch_t - self.ready_t)
                + self.rtt_s + self.t_act_s)


@dataclasses.dataclass
class GatewayMetrics:
    policy: str
    slo_attainment: float
    mean_latency_s: float
    p95_latency_s: float
    interactive_queue_delay_s: float
    batch_queue_delay_s: float
    finished_jobs: int
    dropped_jobs: int
    finished_stages: int
    cold_starts: int
    preemptions: int
    admission_rejections: int
    makespan_s: float
    throughput_stages_per_s: float
    min_headroom_bytes: float
    generated_tokens: int
    # physical paged-KV arena (filled by the gateway post-run): worst-node
    # virtual-over-peak-physical KV ratio, fleet-wide peak mapped pages and
    # peak plane-row utilization
    kv_overcommit_ratio: float = 0.0
    arena_peak_pages: int = 0
    arena_utilization: float = 0.0
    truncated_stages: int = 0
    # node backend that produced this row ("inproc" = cooperative stepping
    # inside the gateway process, "process" = one worker process per node)
    # plus the aggregate worker counters: IPC round trips, wall spent on
    # pipe/pickle overhead (engine compute inside step round trips is
    # excluded — that is worker_step_wall_s), and the worker-measured step
    # wall-clock; per-node breakdown in worker_stats (all zero/empty for
    # the in-process backend)
    node_backend: str = "inproc"
    ipc_calls: int = 0
    ipc_wall_s: float = 0.0
    worker_step_wall_s: float = 0.0
    worker_stats: Dict[int, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    # clock plane (PR 5): which clock produced this row ("virtual" = the
    # deterministic tick clock, "wall" = real monotonic seconds) and the
    # typed run outcome — "deadline_exceeded" + a RunDeadlineExceeded
    # record when the clock's max_run_s fired before every job finished,
    # instead of the old silent max_ticks truncation
    clock: str = "virtual"
    run_outcome: str = "completed"
    run_deadline: Optional[RunDeadlineExceeded] = None
    # wall-clock-only telemetry (zero/empty on the virtual clock so virtual
    # rows stay bit-identical across node backends): makespan in real
    # seconds, per-node engine-busy fraction of the run, and the fleet
    # overlap factor (sum of per-node busy seconds / makespan — above 1.0
    # only when engine compute genuinely overlapped across nodes, which the
    # in-process backend can never achieve)
    wall_makespan_s: float = 0.0
    node_busy_frac: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    overlap_factor: float = 0.0
    # tail percentiles alongside the p95 column: end-to-end job latency,
    # per-stage queue delay and per-stage service latency (ready -> finish).
    # Tail columns (p99/p99.9) are 0.0 on empty or single-sample runs — an
    # extreme-percentile estimate from < 2 observations is noise, and the
    # fleet-summed benchmark paths must never see NaN/inf in a tail cell
    # (see tail_percentile)
    p99_latency_s: float = 0.0
    p999_latency_s: float = 0.0
    queue_delay_p95_s: float = 0.0
    queue_delay_p99_s: float = 0.0
    queue_delay_p999_s: float = 0.0
    stage_latency_p95_s: float = 0.0
    stage_latency_p99_s: float = 0.0
    stage_latency_p999_s: float = 0.0
    # cross-stage prefix-cache plane: prompt tokens the engines would have
    # prefilled vs. tokens served from cached prefix pages, plus the summed
    # per-node index counters (empty when the cache is disabled fleet-wide)
    prefill_tokens_total: int = 0
    prefill_tokens_avoided: int = 0
    prefix_stats: Dict[str, float] = dataclasses.field(default_factory=dict)
    # engine iteration scheduler (chunked prefill / continuous batching):
    # wall-measured TTFT percentiles over finished stages (engine submit ->
    # first output token; 0.0 when no stage carried a stamp — virtual-clock
    # parity suites exclude these, like the other wall-side counters) and
    # the fleet-summed per-iteration token split + compile/fusion counters
    # (deterministic: identical across node backends under either clock)
    ttft_p50_s: float = 0.0
    ttft_p95_s: float = 0.0
    engine_prefill_tokens: int = 0
    engine_decode_tokens: int = 0
    engine_prefill_compiles: int = 0
    engine_fused_steps: int = 0
    engine_steps: int = 0
    # decode horizon (multi-token on-device decode): fleet-summed horizon
    # launches, decode host round-trips, and the headline ratio — host
    # syncs per emitted decode token (1.0 at H=1, ~1/H in pure decode)
    engine_horizon_steps: int = 0
    engine_decode_syncs: int = 0
    host_syncs_per_token: float = 0.0
    # transport + membership plane (PR 7): worker deaths witnessed this
    # run, the in-flight stages evacuated back to the ready queue because
    # of them, end-of-run liveness state per node, idle-ping misses, nodes
    # the straggler detector flags (wall clock only — observations are
    # real seconds; empty on virtual rows so parity holds), and socket
    # transport byte counters (zero for inproc/process backends)
    node_deaths: int = 0
    requeued_stages: int = 0
    death_events: List[NodeDeathEvent] = dataclasses.field(
        default_factory=list)
    liveness: Dict[int, str] = dataclasses.field(default_factory=dict)
    heartbeat_misses: int = 0
    straggler_nodes: List[int] = dataclasses.field(default_factory=list)
    rpc_bytes_sent: int = 0
    rpc_bytes_recv: int = 0
    # fault-injection / tail-scenario plane (PR 9): how long the fleet took
    # to finish the last stage evacuated by a node death (max over deaths of
    # death time -> final requeued-stage finish; 0.0 when no death requeued
    # work or nothing requeued finished), plus per-model demand served —
    # finished stages and generated tokens keyed by resolved model name
    # (the per-family utilization columns in BENCH_tail_scenarios.json)
    recovery_time_s: float = 0.0
    stages_by_model: Dict[str, int] = dataclasses.field(default_factory=dict)
    tokens_by_model: Dict[str, int] = dataclasses.field(default_factory=dict)

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def tail_percentile(xs: List[float], q: float) -> float:
    """Extreme-tail percentile (p99/p99.9) with defined edge cases: fewer
    than two samples returns 0.0 — ``np.percentile`` of an empty array is
    NaN (and would raise on a bare empty list), and a "tail" read off a
    single observation is noise that poisons fleet-summed columns."""
    if len(xs) < 2:
        return 0.0
    return float(np.percentile(xs, q))


class Telemetry:
    """Collects stage events + node headroom samples during a gateway run."""

    def __init__(self) -> None:
        self.events: Dict[int, StageEvent] = {}
        self.headroom: Dict[int, List[float]] = {}
        self.cold_starts = 0
        self.preemptions = 0
        self.admission_rejections = 0
        self.dropped_jobs = 0
        # per-node worker-process counters (process backend only): IPC round
        # trips, pipe/pickle overhead wall, worker-measured step wall-clock
        self.worker_stats: Dict[int, Dict[str, float]] = {}
        # membership plane: worker deaths in arrival order
        self.node_deaths: List[NodeDeathEvent] = []

    # ------------------------------------------------------------- recording
    def event(self, stage_id: int, job_id: int, interactive: bool) -> StageEvent:
        ev = self.events.get(stage_id)
        if ev is None:
            ev = StageEvent(stage_id=stage_id, job_id=job_id,
                            interactive=interactive)
            self.events[stage_id] = ev
        return ev

    def sample_headroom(self, node_id: int, headroom: float) -> None:
        self.headroom.setdefault(node_id, []).append(float(headroom))

    def record_worker(self, node_id: int, stats: Dict[str, float]) -> None:
        """End-of-run snapshot of one worker handle's IPC/wall counters."""
        self.worker_stats[node_id] = dict(stats)

    def node_death(self, ev: NodeDeathEvent) -> None:
        self.node_deaths.append(ev)

    # ------------------------------------------------------------ aggregation
    def summary(self, policy: str, jobs, job_finish: Dict[int, float],
                interactive_budget_s: float, now: float) -> GatewayMetrics:
        """``jobs``: iterable with .job_id, .interactive, .arrival_s,
        .deadline_s and .stages (each stage with .stage_id)."""
        lat: List[float] = []
        slo_ok: List[bool] = []
        int_delays: List[float] = []
        batch_delays: List[float] = []
        for j in jobs:
            waits = sum(self.events[s.stage_id].queue_delay_s
                        for s in j.stages if s.stage_id in self.events
                        and self.events[s.stage_id].finish_t > 0)
            if j.interactive:
                int_delays.append(waits)
            else:
                batch_delays.append(waits)
            if j.job_id not in job_finish:
                slo_ok.append(False)
                continue
            l = job_finish[j.job_id] - j.arrival_s
            lat.append(l)
            if j.interactive:
                slo_ok.append(waits <= interactive_budget_s)
            else:
                slo_ok.append(l <= j.deadline_s)
        finished = [e for e in self.events.values() if e.finish_t > 0]
        makespan = max((e.finish_t for e in finished), default=now)
        head_min = min((min(v) for v in self.headroom.values() if v),
                       default=float("inf"))

        def pct(xs: List[float], q: float, empty: float) -> float:
            return float(np.percentile(xs, q)) if xs else empty

        qdel = [e.queue_delay_s for e in finished]
        slat = [e.finish_t - e.ready_t for e in finished]
        ttft = [e.ttft_s for e in finished if e.ttft_s > 0]
        inf = float("inf")
        recovery: List[float] = []
        for d in self.node_deaths:
            fins = [self.events[s].finish_t for s in d.requeued_stages
                    if s in self.events and self.events[s].finish_t > 0]
            if fins:
                recovery.append(max(fins) - d.t)
        stages_by_model: Dict[str, int] = {}
        tokens_by_model: Dict[str, int] = {}
        for e in finished:
            if e.model:
                stages_by_model[e.model] = stages_by_model.get(e.model, 0) + 1
                tokens_by_model[e.model] = (tokens_by_model.get(e.model, 0)
                                            + e.out_len)
        return GatewayMetrics(
            policy=policy,
            slo_attainment=float(np.mean(slo_ok)) if slo_ok else 0.0,
            mean_latency_s=float(np.mean(lat)) if lat else float("inf"),
            p95_latency_s=pct(lat, 95, inf),
            p99_latency_s=tail_percentile(lat, 99),
            p999_latency_s=tail_percentile(lat, 99.9),
            queue_delay_p95_s=pct(qdel, 95, 0.0),
            queue_delay_p99_s=tail_percentile(qdel, 99),
            queue_delay_p999_s=tail_percentile(qdel, 99.9),
            stage_latency_p95_s=pct(slat, 95, 0.0),
            stage_latency_p99_s=tail_percentile(slat, 99),
            stage_latency_p999_s=tail_percentile(slat, 99.9),
            recovery_time_s=max(recovery, default=0.0),
            stages_by_model=stages_by_model,
            tokens_by_model=tokens_by_model,
            ttft_p50_s=pct(ttft, 50, 0.0),
            ttft_p95_s=pct(ttft, 95, 0.0),
            prefill_tokens_total=sum(e.prompt_tokens for e in finished),
            prefill_tokens_avoided=sum(e.prefill_avoided for e in finished),
            interactive_queue_delay_s=(float(np.mean(int_delays))
                                       if int_delays else 0.0),
            batch_queue_delay_s=(float(np.mean(batch_delays))
                                 if batch_delays else 0.0),
            finished_jobs=len(job_finish),
            dropped_jobs=self.dropped_jobs,
            finished_stages=len(finished),
            cold_starts=self.cold_starts,
            preemptions=self.preemptions,
            admission_rejections=self.admission_rejections,
            makespan_s=float(makespan),
            throughput_stages_per_s=(len(finished) / makespan
                                     if makespan > 0 else 0.0),
            min_headroom_bytes=float(head_min),
            generated_tokens=sum(e.out_len for e in finished))
