"""Multi-process node runtimes: one OS process per ``NodeRuntime``.

The in-process gateway cooperatively steps every node inside its own
interpreter, so cross-node overlap is emulated, not real. This module moves
each node into a child process and gives the gateway a :class:`NodeHandle`
proxy that speaks a small request/reply protocol over ``multiprocessing``
pipes — submit / step / poll-finished / make_room / signal snapshots plus
the admission and routing estimates the Substrate protocol needs. The
handle implements the exact node-facing surface ``ClusterGateway`` consumes
(``signal`` / ``can_admit`` / ``t_act`` / ``degradation_cost`` / ``submit``
/ ``preempt`` / ``step`` / ``acc.headroom`` / ``kv_stats``), so the
gateway's dispatch change is a thin backend switch, not a rewrite.

Design points:

- Children are SPAWNED (never forked): each worker re-imports JAX fresh and
  builds its own model zoo + ``NodeRuntime`` from a picklable
  :class:`WorkerSpec`; jitted executables and device buffers never cross
  the pipe. Only plain data does (``Request`` objects, ``NodeSignal``
  snapshots, float estimates).
- ``step`` replies carry (finished requests, per-request decode progress,
  measured worker wall-clock). Progress lets the gateway's boundary
  preemption rank victims exactly as it does in-process, where it can read
  ``req.out`` directly.
- The handle counts every round trip (``ipc_calls``, ``ipc_wall_s``) and
  accumulates the worker-reported step wall-clock (``worker_step_wall_s``)
  — the per-node IPC-overhead counters surfaced through gateway telemetry.
- Wall-clock free-run (``set_continuous``): under the gateway's wall clock
  a child steps its own engines whenever they hold work, buffering finished
  requests for the next ``poll_finished`` round trip — engine iterations
  genuinely overlap across processes in *measured* time, with pipe requests
  still serviced at every engine-step boundary (so preemption/admission
  stay boundary-consistent). Virtual runs never enable this mode.
- Determinism: the protocol is synchronous request/reply per node, and the
  gateway collects step replies in node order, so a "process" run under the
  deterministic virtual clock reproduces the in-process completion sets and
  metrics bit-for-bit (see ``tests/test_worker.py``). Scope of that
  guarantee: it holds for every policy in the registry, none of which reads
  node state from ``priority``/``on_finish``. A custom policy that issues a
  node read (e.g. ``sub.signal``) while the gateway is draining the tick's
  step replies observes POST-step state here (the worker already executed
  the broadcast step) but pre-step state in-process — that window is the
  price of real concurrency; keep node reads inside ``route``/``reservation``
  (which run before the broadcast) to stay backend-identical.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import multiprocessing as mp
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.serving import transport
from repro.serving.engine import PromptTooLongError, Request

_SHUTDOWN_TIMEOUT_S = 5.0
# free-running children with idle engines block on the pipe this long per
# loop pass instead of spinning (wall-clock continuous mode only)
_IDLE_POLL_S = 0.005
# how long a locally spawned socket child may take to bind + report its port
# (no JAX import happens before the report, so this is pure process startup)
_BOOT_TIMEOUT_S = 60.0
#: method-surface version carried in the socket hello handshake — bumped
#: when the request/reply method set changes (the frame format has its own
#: independent version, ``transport.FRAME_VERSION``)
PROTOCOL_VERSION = 1


class WorkerDied(RuntimeError):
    """A worker's transport failed mid-protocol: the process was killed
    (OOM/segfault/SIGKILL) or the socket peer vanished. Carries the node id
    so the gateway's membership plane can evacuate exactly that node."""

    def __init__(self, node_id: int, msg: str):
        super().__init__(msg)
        self.node_id = node_id


@dataclasses.dataclass
class WorkerSpec:
    """Everything a child needs to rebuild its node — plain picklable data.

    The child constructs its own zoo/host trees from ``model_names`` +
    ``seed`` (same deterministic init path as ``cluster.build_zoo``), so a
    worker node is numerically identical to the in-process node the same
    spec would build."""
    node_id: int
    cluster_id: int
    model_names: Tuple[str, ...]
    # None = use NodeRuntime's own defaults, so the two backends cannot
    # silently drift if those defaults change
    hbm_budget: Optional[float] = None
    max_slots: Optional[int] = None
    s_max: Optional[int] = None
    ctx_bytes: Optional[int] = None
    page_tokens: Optional[int] = None
    prefix_cache: Optional[bool] = None
    prefix_cache_pages: Optional[int] = None
    # engine iteration-scheduler knobs (None = NodeRuntime defaults):
    # max_batch_tokens caps decode positions + prefill chunk tokens per
    # fused iteration; prefill_chunk_tokens > 0 enables chunked prefill
    max_batch_tokens: Optional[int] = None
    prefill_chunk_tokens: Optional[int] = None
    # decode_horizon > 1 fuses that many decode iterations per host sync
    decode_horizon: Optional[int] = None
    seed: int = 1
    # extra XLA_FLAGS applied inside the child BEFORE its XLA client forms
    # (e.g. "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
    # to run a worker single-threaded) — an operator knob for wall-clock
    # fleets on thread-oversubscribed hosts; measure before enabling, the
    # per-child pool sometimes wins anyway. None = inherit the parent
    # environment unchanged, which is what the bit-identical virtual
    # parity guarantee is stated for (thread partitioning can perturb
    # last-ulp numerics).
    xla_flags: Optional[str] = None


def _worker_main(conn, spec: WorkerSpec) -> None:
    """Child entry point: build the runtime, then serve the request loop.

    Heavy imports happen here, inside the spawned interpreter — the parent
    never ships device state. Every post-boot reply is ``(kind, payload,
    compute_wall_s)`` with kind in {"ok", "prompt_too_long", "err"};
    ``compute_wall_s`` is the child-measured time spent executing the
    method, so the parent can charge only the residual (pipe + pickle) to
    its IPC-overhead counter. Boot replies are ``("ready"|"boot_error",
    payload)``."""
    try:
        if spec.xla_flags:
            # must land before the child's first computation (the XLA
            # client parses XLA_FLAGS when it is created, not at import)
            import os
            os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                       + " " + spec.xla_flags).strip()
        from repro.serving.cluster import build_zoo
        from repro.serving.node_runtime import NodeRuntime
        zoo, host = build_zoo(spec.model_names, seed=spec.seed)
        kw = {k: v for k, v in (("hbm_budget", spec.hbm_budget),
                                ("max_slots", spec.max_slots),
                                ("s_max", spec.s_max),
                                ("ctx_bytes", spec.ctx_bytes),
                                ("page_tokens", spec.page_tokens),
                                ("prefix_cache", spec.prefix_cache),
                                ("prefix_cache_pages",
                                 spec.prefix_cache_pages),
                                ("max_batch_tokens", spec.max_batch_tokens),
                                ("prefill_chunk_tokens",
                                 spec.prefill_chunk_tokens),
                                ("decode_horizon", spec.decode_horizon))
              if v is not None}
        node = NodeRuntime(spec.node_id, spec.cluster_id, zoo, host, **kw)
        conn.send(("ready", {"profiles": node.profiles,
                             "max_slots": node.max_slots,
                             "s_max": node.s_max}))
    except Exception:
        conn.send(("boot_error", traceback.format_exc()))
        return
    # wall-clock free-running mode (set via the "continuous" method): the
    # child steps its engines whenever they hold work, buffering finished
    # requests for the gateway's next "poll", and services pipe requests
    # with priority at every engine-step boundary. The default (continuous
    # off) is the original strict request/reply loop, untouched — virtual
    # runs stay bit-identical.
    continuous = False
    buffered: Dict[str, List[Request]] = {}
    buffered_wall = 0.0
    while True:
        if continuous:
            has_work = node.has_work()
            try:
                ready = conn.poll(0.0 if has_work else _IDLE_POLL_S)
            except (EOFError, OSError):
                break
            if not ready:
                if has_work:
                    t0 = time.perf_counter()
                    out = node.step()
                    for eng in node.engines.values():
                        if eng.waiting and eng.free_slots:
                            # admission blocked on memory, not slots: the
                            # gateway admitted against a boundary-stale
                            # headroom report, so reclaim locally (Alg. 2
                            # cheap prefix; no-op when headroom suffices)
                            # instead of waiting for a release that may
                            # never come
                            node.make_room(eng._r_need(eng.waiting[0]))
                    buffered_wall += time.perf_counter() - t0
                    for m, reqs in out.items():
                        buffered.setdefault(m, []).extend(reqs)
                continue
        try:
            method, args = conn.recv()
        except (EOFError, OSError, KeyboardInterrupt):
            break
        if method == "shutdown":
            conn.send(("ok", None, 0.0))
            break
        t0 = time.perf_counter()
        try:
            if method == "step":
                out = node.step()
                progress = {rid: len(r.out)
                            for eng in node.engines.values()
                            for rid, r in eng.active.items()}
                payload = (out, progress)
            elif method == "continuous":
                continuous = bool(args[0])
                payload = None
            elif method == "poll":
                # drain the free-run buffer: finished requests by model,
                # current decode progress, the engine-step wall clock
                # accumulated since the last poll, and a fresh NodeSignal —
                # the periodic node->scheduler report of §III that lets the
                # wall-clock gateway route/admit WITHOUT a synchronous
                # round trip per decision (each one blocks until the next
                # engine-step boundary)
                progress = {rid: len(r.out)
                            for eng in node.engines.values()
                            for rid, r in eng.active.items()}
                payload = (buffered, progress, buffered_wall,
                           node.signal())
                buffered, buffered_wall = {}, 0.0
            elif method == "ping":
                # idle-period liveness probe from the membership plane: a
                # no-op round trip whose reply is the heartbeat
                payload = None
            elif method == "headroom":
                payload = node.acc.headroom
            elif method == "acc_can_admit":
                payload = node.acc.can_admit(*args)
            else:
                # signal / can_admit / t_act / degradation_cost / make_room
                # / submit / preempt / activate / sleep / kv_stats
                payload = getattr(node, method)(*args)
            conn.send(("ok", payload, time.perf_counter() - t0))
        except PromptTooLongError as e:
            conn.send(("prompt_too_long", str(e),
                       time.perf_counter() - t0))
        except Exception:
            conn.send(("err", traceback.format_exc(),
                       time.perf_counter() - t0))


class _AccProxy:
    """The two accountant reads the gateway makes (`headroom` for telemetry
    sampling, `can_admit` for the submit-time make_room check), forwarded to
    the worker's real ``MemoryAccountant``."""

    def __init__(self, handle: "NodeHandle"):
        self._h = handle

    @property
    def headroom(self) -> float:
        return self._h._call("headroom")

    def can_admit(self, r_need: float) -> bool:
        return self._h._call("acc_can_admit", r_need)


class NodeHandle:
    """Gateway-side proxy for one worker process hosting a ``NodeRuntime``.

    Synchronous surface mirrors the runtime 1:1; ``step_send``/``step_recv``
    split the step round trip so the gateway can broadcast one tick to every
    worker and let the engine iterations genuinely overlap across processes
    before collecting replies in deterministic node order."""

    backend = "process"

    def __init__(self, spec: WorkerSpec, ctx=None):
        ctx = ctx or mp.get_context("spawn")
        self._init_state(spec)
        self._conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_worker_main, args=(child, spec),
                                name=f"maestro-node-{spec.node_id}",
                                daemon=True)
        try:
            self.proc.start()
        except Exception:
            self.close()
            raise
        child.close()

    def _init_state(self, spec: WorkerSpec) -> None:
        """Transport-independent handle state; set FIRST so ``close`` is
        safe on a handle whose transport setup failed halfway."""
        self.spec = spec
        self.node_id = spec.node_id
        self.cluster_id = spec.cluster_id
        self._closed = False
        self._ready = False
        # IPC-overhead + worker wall-clock counters (gateway telemetry)
        self.ipc_calls = 0
        self.ipc_wall_s = 0.0
        self.worker_step_wall_s = 0.0
        # idle-period pings still unanswered when the next came due
        # (membership plane; see ping_send)
        self.heartbeat_misses = 0
        self.acc = _AccProxy(self)
        self.profiles: Dict[str, Any] = {}
        self.max_slots = spec.max_slots
        self.s_max = spec.s_max
        # prompt page granularity, for gateway-side digest computation
        # (must match NodeRuntime's page_tokens default)
        self.page_tokens = spec.page_tokens or 16
        self._inflight = 0            # submitted minus finished/preempted
        self._progress: Dict[int, int] = {}
        self._step_pending = False
        self._step_buffer: Optional[Dict[str, List[Request]]] = None
        # wall-clock free-run bookkeeping: the pipe is FIFO, so every
        # outstanding request's reply arrives in send order — `_expected`
        # records what each upcoming reply is (("poll",) / ("submit", rid)
        # / ("ping",) / ("sync", method)) and replies are folded into
        # handle state as they are consumed
        self._expected: collections.deque = collections.deque()
        self._finished_buf: Dict[str, List[Request]] = {}
        self._submit_errors: List[int] = []
        self._poll_pending = False
        self._ping_pending = False
        self._cached_signal = None    # last NodeSignal piggybacked on a poll

    # ------------------------------------------------------------- lifecycle
    def wait_ready(self) -> "NodeHandle":
        """Block until the child built its runtime (spawn boots in parallel
        across a fleet: start all handles first, then wait on each)."""
        if self._ready:
            return self
        try:
            kind, payload = self._conn.recv()
        except (EOFError, OSError):
            self.close()
            raise WorkerDied(
                self.node_id,
                f"node {self.node_id} worker died during boot "
                f"({self._exit_status()}); note: spawn re-imports "
                f"the parent __main__, which must be an importable file")
        if kind != "ready":
            self.close()
            raise RuntimeError(
                f"node {self.node_id} worker failed to boot:\n{payload}")
        self.profiles = payload["profiles"]
        self.max_slots = payload["max_slots"]
        self.s_max = payload["s_max"]
        self._ready = True
        return self

    def _exit_status(self) -> str:
        proc = getattr(self, "proc", None)
        if proc is not None:
            return f"exitcode={proc.exitcode}"
        return f"remote worker at {getattr(self, 'address', None)}"

    def close(self) -> None:
        """Idempotent shutdown, safe on half-constructed handles (partial
        fleet spawn) and on remote handles that own no local process."""
        if getattr(self, "_closed", False):
            return
        self._closed = True
        conn = getattr(self, "_conn", None)
        proc = getattr(self, "proc", None)
        peer_up = proc.is_alive() if proc is not None else conn is not None
        if peer_up and conn is not None:
            try:
                conn.send(("shutdown", ()))
                if conn.poll(_SHUTDOWN_TIMEOUT_S):
                    conn.recv()
            except (BrokenPipeError, EOFError, OSError):
                pass
        if proc is not None and getattr(proc, "_popen", None) is not None:
            # (guard: join on a never-started Process raises)
            proc.join(timeout=_SHUTDOWN_TIMEOUT_S)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=_SHUTDOWN_TIMEOUT_S)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass

    def __del__(self):  # best-effort: never leak a worker
        try:
            if getattr(self, "proc", None) is not None and self.proc.is_alive():
                self.proc.terminate()
        except Exception:
            pass

    # -------------------------------------------------------------- protocol
    def _call(self, method: str, *args):
        self.wait_ready()
        if self._step_pending:
            # a synchronous call while a step reply is in flight (e.g. a
            # custom policy reading signal() from an on_finish hook): collect
            # and buffer the step payload first so replies cannot mis-pair
            self._step_buffer = self._recv_step()
        t0 = time.perf_counter()
        self._send(method, args)
        self._expected.append(("sync", method))
        # asynchronous replies queued ahead of ours (armed polls, async
        # submits — the pipe is FIFO and the child drains every pending
        # request at one engine-step boundary) are folded into handle state
        # on the way to our reply: one boundary wait covers them all
        while True:
            tag = self._expected.popleft()
            if tag[0] != "sync":
                self._fold_async(tag)
                continue
            kind, payload, compute_wall = self._recv(method)
            self.ipc_calls += 1
            # only the residual over the child-measured method execution is
            # IPC overhead — a submit that pays a real activation
            # (device_put of weights) must not read as pipe/pickle cost
            self.ipc_wall_s += max(0.0,
                                   time.perf_counter() - t0 - compute_wall)
            if kind == "prompt_too_long":
                raise PromptTooLongError(payload)
            if kind != "ok":
                raise RuntimeError(
                    f"node {self.node_id} worker error in "
                    f"{method}:\n{payload}")
            return payload

    def _fold_async(self, tag) -> None:
        """Receive ONE asynchronous reply and fold it into handle state.
        The pipe is FIFO, so ``tag`` (the head of ``_expected``) is what
        this reply must be."""
        kind, payload, _ = self._recv(tag[0])
        self.ipc_calls += 1
        if tag[0] == "poll":
            self._poll_pending = False
            if kind != "ok":
                raise RuntimeError(
                    f"node {self.node_id} worker error in poll:\n{payload}")
            out, progress, step_wall, self._cached_signal = payload
            self.worker_step_wall_s += step_wall
            self._progress = progress
            for model, reqs in out.items():
                self._finished_buf.setdefault(model, []).extend(reqs)
                self._inflight -= len(reqs)
        elif tag[0] == "submit":
            if kind == "prompt_too_long":
                # typed rejection of an async submit: surfaced to the
                # gateway via take_submit_errors (the stage finishes
                # truncated, exactly like the synchronous path)
                self._inflight -= 1
                self._submit_errors.append(tag[1])
            elif kind != "ok":
                raise RuntimeError(
                    f"node {self.node_id} worker error in async "
                    f"submit:\n{payload}")
        elif tag[0] == "ping":
            self._ping_pending = False
            if kind != "ok":                     # pragma: no cover
                raise RuntimeError(
                    f"node {self.node_id} worker error in ping:\n{payload}")
        else:                                    # pragma: no cover
            raise AssertionError(f"unknown async reply tag {tag!r}")

    # -------------------------------------------- node surface (gateway API)
    def signal(self):
        return self._call("signal")

    def can_admit(self, r_need: float, model: Optional[str] = None) -> bool:
        return self._call("can_admit", r_need, model)

    def t_act(self, model: str) -> float:
        return self._call("t_act", model)

    def degradation_cost(self, r_need: float) -> Optional[float]:
        return self._call("degradation_cost", r_need)

    def make_room(self, r_need: float) -> None:
        self._call("make_room", r_need)

    def submit(self, model: str, req: Request) -> None:
        self._call("submit", model, req)
        self._inflight += 1

    def preempt(self, model: str, req_id: int) -> Optional[Request]:
        req = self._call("preempt", model, req_id)
        if req is not None:
            self._inflight -= 1
            self._progress.pop(req_id, None)
        return req

    def kv_stats(self) -> Dict[str, float]:
        return self._call("kv_stats")

    # ------------------------------------------------- wall-clock free-run
    def set_continuous(self, on: bool = True) -> None:
        """Switch the child into (or out of) free-running mode: it steps
        its engines on its own whenever they hold work and buffers finished
        requests until the next :meth:`poll_finished`. Used by the gateway's
        wall clock; virtual runs never enable it."""
        self._call("continuous", bool(on))

    def has_work(self) -> bool:
        """Submitted-but-unfinished requests outstanding on this node (the
        gateway polls only such nodes — an idle worker costs no round
        trips)."""
        return self._inflight > 0

    def poll_send(self) -> None:
        """Arm a drain request at the free-running child without waiting for
        the reply (at most one poll is outstanding per worker). The child
        answers at its next engine-step boundary; the gateway folds the
        reply in with :meth:`drain_ready` on a later loop pass, so the
        wall-clock dispatch loop NEVER blocks on worker compute. Idle
        workers are skipped entirely."""
        if self._poll_pending or self._inflight == 0:
            return
        self.wait_ready()
        self._send("poll", ())
        self._expected.append(("poll",))
        self._poll_pending = True

    def drain_ready(self) -> Dict[str, List[Request]]:
        """Fold every reply already sitting in the pipe (poll reports,
        async submit acks) into handle state WITHOUT blocking, then return
        the finished requests accumulated since the last drain."""
        while self._expected and self._conn.poll(0):
            self._fold_async(self._expected.popleft())
        out, self._finished_buf = self._finished_buf, {}
        return out

    def submit_send(self, model: str, req: Request) -> None:
        """Asynchronous submit: fire the request and return immediately;
        the ack (or typed prompt-too-long rejection, surfaced through
        :meth:`take_submit_errors`) is folded in on a later drain — the
        pipe's FIFO order keeps reply pairing exact. A synchronous submit
        blocks until the child's engine-step boundary, which at wide batch
        sizes would stall the wall-clock dispatch loop for every stage."""
        self.wait_ready()
        self._send("submit", (model, req))
        self._expected.append(("submit", req.req_id))
        self._inflight += 1

    def ping_send(self) -> None:
        """Idle-period liveness probe (membership plane): fire a no-op
        round trip whose reply — folded in by :meth:`drain_ready` — is the
        heartbeat. Busy nodes are never pinged (their poll replies already
        carry liveness); if the previous ping is still unanswered when the
        next comes due, that is counted as a *heartbeat miss* instead of
        stacking another request behind a stalled worker."""
        if self._inflight > 0:
            return
        if self._ping_pending:
            self.heartbeat_misses += 1
            return
        self.wait_ready()
        self._send("ping", ())
        self._expected.append(("ping",))
        self._ping_pending = True

    def take_submit_errors(self) -> List[int]:
        """Request ids whose async submit was rejected (PromptTooLongError
        in the child) since the last call; the gateway finishes them
        truncated, mirroring the synchronous error path."""
        out, self._submit_errors = self._submit_errors, []
        return out

    def poll_finished(self) -> Dict[str, List[Request]]:
        """Blocking poll round trip: arm a poll (if none is outstanding)
        and wait for the child's report; returns everything finished since
        the last drain. Used by warmup; the serving loop uses the
        non-blocking poll_send/drain_ready pair instead."""
        self.poll_send()
        while self._poll_pending and self._expected:
            self._fold_async(self._expected.popleft())
        out, self._finished_buf = self._finished_buf, {}
        return out

    def last_signal(self):
        """The NodeSignal piggybacked on the most recent poll reply (None
        before the first poll). Under the wall clock the gateway schedules
        against this boundary-fresh report instead of blocking a synchronous
        signal/admission round trip per decision."""
        return self._cached_signal

    # ------------------------------------------------------------------ step
    def step_send(self) -> None:
        """Fire one engine iteration without waiting for the reply. Idle
        workers (nothing submitted and not yet finished) are skipped — an
        engine step with no waiting/active work is a no-op, so skipping the
        round trip changes nothing but the IPC bill."""
        if self._inflight == 0:
            self._step_pending = False
            return
        self.wait_ready()
        self._send("step", ())
        self._step_pending = True

    def step_recv(self) -> Dict[str, List[Request]]:
        """Collect the reply of the last ``step_send`` (finished requests by
        model), folding the worker's measured step wall-clock and per-request
        decode progress into the handle."""
        if self._step_buffer is not None:
            out, self._step_buffer = self._step_buffer, None
            return out
        if not self._step_pending:
            return {}
        return self._recv_step()

    def _send(self, method: str, args: tuple) -> None:
        """One request onto the transport; a dead peer surfaces as a typed
        :class:`WorkerDied` (node id attached) instead of a bare
        BrokenPipeError, so the gateway's membership plane can evacuate."""
        try:
            self._conn.send((method, args))
        except (BrokenPipeError, EOFError, OSError):
            raise WorkerDied(
                self.node_id,
                f"node {self.node_id} worker died before {method!r} "
                f"({self._exit_status()})")

    def _recv(self, method: str):
        """One reply off the transport; a dead peer surfaces as a typed
        :class:`WorkerDied` instead of a bare EOFError."""
        try:
            return self._conn.recv()
        except (EOFError, OSError):
            raise WorkerDied(
                self.node_id,
                f"node {self.node_id} worker died during {method!r} "
                f"({self._exit_status()})")

    def _recv_step(self) -> Dict[str, List[Request]]:
        # measure from recv START (not from the broadcast): time a reply
        # spends ready in the pipe while the gateway drains earlier nodes
        # is neither this node's compute nor IPC overhead
        t0 = time.perf_counter()
        kind, payload, step_wall = self._recv("step")
        elapsed = time.perf_counter() - t0
        self.ipc_calls += 1
        self._step_pending = False
        if kind != "ok":
            raise RuntimeError(
                f"node {self.node_id} worker error in step:\n{payload}")
        out, self._progress = payload
        # the step round trip is dominated by real engine compute; only the
        # residual (pipe + pickling + scheduling) is IPC overhead — charging
        # the whole wait would double-count worker_step_wall_s and inflate
        # the fleet-summed overhead by ~n_nodes under the overlapped tick.
        # (If the reply was not ready yet, elapsed still contains remaining
        # compute; subtracting the full step wall clamps that to 0 — the
        # counter may under-read pipe cost but never inflates it.)
        self.ipc_wall_s += max(0.0, elapsed - step_wall)
        self.worker_step_wall_s += step_wall
        for reqs in out.values():
            self._inflight -= len(reqs)
        return out

    def step(self) -> Dict[str, List[Request]]:
        self.step_send()
        return self.step_recv()

    def out_len(self, req_id: int) -> int:
        """Decode progress of an in-flight request as of the last collected
        step — the process-backend stand-in for reading ``req.out`` on the
        engine's own Request object."""
        return self._progress.get(req_id, 0)

    def worker_stats(self) -> Dict[str, float]:
        return {"ipc_calls": int(self.ipc_calls),
                "ipc_wall_s": float(self.ipc_wall_s),
                "worker_step_wall_s": float(self.worker_step_wall_s),
                "heartbeat_misses": int(self.heartbeat_misses)}


# ---------------------------------------------------------------------------
# socket backend: the same handle over the framed TCP transport
# ---------------------------------------------------------------------------

def _serve_conn(conn) -> None:
    """One gateway connection: validate the hello handshake (protocol
    version + WorkerSpec), then run the standard worker loop over the
    framed transport — ``_worker_main`` is transport-agnostic."""
    try:
        msg = conn.recv()
    except (EOFError, OSError):
        return
    if not (isinstance(msg, tuple) and len(msg) == 3 and msg[0] == "hello"):
        conn.send(("boot_error",
                   f"expected ('hello', version, WorkerSpec) handshake, "
                   f"got {type(msg).__name__}"))
        return
    _, version, spec = msg
    if version != PROTOCOL_VERSION:
        conn.send(("boot_error",
                   f"gateway speaks worker protocol {version}, this worker "
                   f"speaks {PROTOCOL_VERSION} — rebuild one side"))
        return
    _worker_main(conn, spec)


def _socket_child_main(bootstrap, host: str) -> None:
    """Locally spawned socket worker: bind an ephemeral port, report it over
    the one-shot bootstrap pipe, serve exactly one gateway connection."""
    srv = transport.listen(host, 0)
    bootstrap.send(srv.getsockname()[1])
    bootstrap.close()
    conn = transport.accept(srv)
    srv.close()
    try:
        _serve_conn(conn)
    finally:
        conn.close()


class SocketNodeHandle(NodeHandle):
    """:class:`NodeHandle` whose connection is a :class:`FrameTransport`
    over TCP instead of a multiprocessing pipe. All protocol machinery —
    the FIFO ``_expected`` pairing, the async poll/submit hot path, step
    broadcast, heartbeats — is inherited untouched: both connections expose
    the same ``send``/``recv``/``poll``/``close`` surface.

    Two ways to get one:

    - constructor: spawn the worker locally (child binds an ephemeral
      localhost port, reports it over a one-shot bootstrap pipe, parent
      connects) — this is what ``build_fleet(backend="socket")`` does, and
      it is protocol-identical to a remote worker;
    - :meth:`connect`: attach to a worker already listening elsewhere,
      started standalone with ``python -m repro.serving.worker --listen``.
    """

    backend = "socket"

    def __init__(self, spec: WorkerSpec, ctx=None, host: str = "127.0.0.1",
                 boot_timeout_s: float = _BOOT_TIMEOUT_S):
        ctx = ctx or mp.get_context("spawn")
        self._init_state(spec)
        boot, child_boot = ctx.Pipe()
        self.proc = ctx.Process(target=_socket_child_main,
                                args=(child_boot, host),
                                name=f"maestro-socket-node-{spec.node_id}",
                                daemon=True)
        try:
            self.proc.start()
            child_boot.close()
            if not boot.poll(boot_timeout_s):
                raise WorkerDied(
                    self.node_id,
                    f"node {self.node_id} socket worker never reported "
                    f"its port ({self._exit_status()})")
            port = boot.recv()
            self.address = (host, int(port))
            self._conn = transport.connect(self.address)
            self._conn.send(("hello", PROTOCOL_VERSION, spec))
        except (EOFError, OSError) as e:
            self.close()
            raise WorkerDied(
                self.node_id,
                f"node {self.node_id} socket worker died while binding "
                f"({self._exit_status()}): {e}")
        except Exception:
            self.close()
            raise
        finally:
            boot.close()

    @classmethod
    def connect(cls, address, spec: WorkerSpec,
                timeout_s: float = 30.0) -> "SocketNodeHandle":
        """Attach to an already-running worker (``python -m
        repro.serving.worker --listen HOST:PORT`` on the other host).
        ``address`` is ``"host:port"`` or a ``(host, port)`` tuple; the
        returned handle owns no local process (``proc is None``)."""
        self = cls.__new__(cls)
        self._init_state(spec)
        self.proc = None
        self.address = (transport.parse_address(address)
                        if isinstance(address, str) else
                        (address[0], int(address[1])))
        try:
            self._conn = transport.connect(self.address, timeout_s=timeout_s)
            self._conn.send(("hello", PROTOCOL_VERSION, spec))
        except OSError as e:
            self.close()
            raise WorkerDied(
                self.node_id,
                f"node {self.node_id}: cannot reach worker at "
                f"{self.address[0]}:{self.address[1]}: {e}")
        return self

    def worker_stats(self) -> Dict[str, float]:
        s = super().worker_stats()
        conn = getattr(self, "_conn", None)
        if conn is not None:
            # transport-overhead columns for BENCH_gateway_socket.json
            s["bytes_sent"] = int(conn.bytes_sent)
            s["bytes_recv"] = int(conn.bytes_recv)
        return s


# ---------------------------------------------------------------------------
# fleet lifecycle
# ---------------------------------------------------------------------------

_HANDLE_CLASSES = {"process": NodeHandle, "socket": SocketNodeHandle}


def spawn_fleet(specs: Sequence[WorkerSpec],
                backend: str = "process") -> List[NodeHandle]:
    """Spawn one worker per spec, booting in parallel: all processes start
    before any ready handshake is awaited, so fleet boot costs the slowest
    node, not the sum. If any constructor or handshake fails, every
    already-started worker is torn down before the error propagates — a
    failed spawn leaks no processes."""
    try:
        cls = _HANDLE_CLASSES[backend]
    except KeyError:
        raise ValueError(f"unknown worker backend {backend!r} "
                         f"(expected one of {sorted(_HANDLE_CLASSES)})")
    ctx = mp.get_context("spawn")
    handles: List[NodeHandle] = []
    try:
        for s in specs:
            handles.append(cls(s, ctx=ctx))
        for h in handles:
            h.wait_ready()
    except Exception:
        close_fleet(handles)
        raise
    return handles


def connect_fleet(addresses: Sequence[Any],
                  specs: Sequence[WorkerSpec]) -> List[NodeHandle]:
    """Attach to standalone socket workers already listening at
    ``addresses`` ("host:port" strings or tuples, one per spec, same
    order). Same teardown-on-failure contract as :func:`spawn_fleet`."""
    if len(addresses) != len(specs):
        raise ValueError(f"{len(addresses)} addresses for "
                         f"{len(specs)} specs")
    handles: List[NodeHandle] = []
    try:
        for addr, spec in zip(addresses, specs):
            handles.append(SocketNodeHandle.connect(addr, spec))
        for h in handles:
            h.wait_ready()
    except Exception:
        close_fleet(handles)
        raise
    return handles


def close_fleet(fleet: Sequence[Any]) -> None:
    """Shut down every worker handle in a (possibly mixed) fleet; in-process
    ``NodeRuntime`` members are left untouched. Safe to call even when the
    gateway was never constructed (the constructor-failure path), safe on
    half-constructed handles, and safe to call twice — handle close is
    idempotent and a close failure never strands the rest of the fleet."""
    for node in fleet:
        if hasattr(node, "close"):
            try:
                node.close()
            except Exception:       # best-effort teardown: keep going
                traceback.print_exc()


# ---------------------------------------------------------------------------
# standalone worker entry point (remote hosts)
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> None:
    """``python -m repro.serving.worker --listen HOST:PORT`` — run a worker
    that serves gateway connections over the socket transport. The node's
    configuration (``WorkerSpec``) arrives in the gateway's hello, so one
    listening worker can serve successive runs with different specs."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.worker",
        description="Standalone Maestro worker node (socket transport). "
                    "TRUSTED NETWORKS ONLY: the wire protocol is pickle.")
    ap.add_argument("--listen", required=True, metavar="HOST:PORT",
                    help="bind address (port 0 picks an ephemeral port)")
    ap.add_argument("--once", action="store_true",
                    help="exit after serving one gateway connection "
                         "instead of accepting the next")
    args = ap.parse_args(argv)
    host, port = transport.parse_address(args.listen)
    srv = transport.listen(host, port)
    bound = srv.getsockname()
    print(f"[worker] listening on {bound[0]}:{bound[1]}", flush=True)
    try:
        while True:
            conn = transport.accept(srv)
            try:
                _serve_conn(conn)
            finally:
                conn.close()
            if args.once:
                break
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()


if __name__ == "__main__":
    main()
