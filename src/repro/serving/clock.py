"""The clock plane: pluggable time/event machinery for the live gateway.

Before this module existed, time was smeared across ``ClusterGateway`` — a
tick counter, ``tick_s`` arithmetic in ``now``/``t_exec_est``/``_deadline``,
refresh cadences counted in ticks, RTT/T_act modelled as per-tick scans over
in-flight records, and a magic ``max_ticks`` heuristic in ``run()``. This
module extracts all of it behind one :class:`Clock` protocol with two
implementations:

- :class:`VirtualClock` — the deterministic step-driven clock every test and
  cross-PR BENCH baseline depends on. One ``advance()`` is one tick of
  ``tick_s`` virtual seconds; delayed events (RTT + activation transit)
  release on the first tick at/after their due time, **in schedule order**
  within a tick — exactly reproducing the old insertion-ordered
  ``_flush_submissions`` scan, so virtual runs stay bit-identical to the
  pre-refactor gateway on both node backends.
- :class:`WallClock` — real monotonic time. Events release when wall time
  passes them (release order), ``advance()`` sleeps until the next known
  wake-up (arrival, event release, or a short poll interval while work is in
  flight), and queue delay / SLO attainment are measured in real elapsed
  seconds. Under this clock the worker fleet free-runs: engine iterations
  genuinely overlap across processes in *measured* time.

Both clocks enforce the run deadline (``GatewayConfig.max_run_s``): the
gateway loop asks ``expired()`` instead of counting ticks, and a run cut
short reports a typed :class:`RunDeadlineExceeded` outcome in its metrics
instead of silently truncating.

Periodic work (aging refresh, telemetry sampling) goes through
``Clock.cadence(period_s)``: the virtual clock converts the period to a
whole number of ticks and fires on the tick modulus (bit-identical to the
old ``tick % every == 0`` checks), the wall clock fires whenever real time
passes the next due point. Periods are expressed in SECONDS everywhere, so
policy hysteresis and cadences are clock-independent.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, List, Optional, Protocol, runtime_checkable

#: Slack applied when deciding an event is due on the virtual clock — the
#: same epsilon the old per-tick ``submit_at > now + 1e-9`` scan used.
EPS = 1e-9

#: Hard cap on a single wall-clock sleep: even with a far-off wake-up the
#: loop re-checks at least this often (arrivals can't starve the deadline).
MAX_WALL_SLEEP_S = 0.1


@dataclasses.dataclass(frozen=True)
class RunDeadlineExceeded:
    """Typed run outcome: the clock's run deadline fired before every job
    finished. Recorded in ``GatewayMetrics.run_deadline`` (and mirrored by
    ``run_outcome == "deadline_exceeded"``) instead of the pre-clock-plane
    behavior of silently returning truncated metrics."""
    max_run_s: float              # the deadline that fired (clock seconds)
    elapsed_s: float              # clock time when the run stopped
    unfinished_jobs: int          # jobs neither finished nor dropped


class Cadence(Protocol):
    """Periodic trigger bound to one clock; ``due()`` is polled once per
    gateway loop iteration."""

    def due(self) -> bool: ...


@runtime_checkable
class Clock(Protocol):
    """What the gateway's event-driven core needs from time.

    ``call_at`` schedules a delayed release (RTT / cold-start transit);
    ``pop_due`` returns every released payload; ``advance`` moves time
    forward (one tick, or a real sleep until ``until``); ``expired`` is the
    run-deadline guard. ``name`` tags telemetry rows ("virtual" / "wall").
    """

    name: str

    def now(self) -> float: ...

    def call_at(self, t: float, payload: Any) -> None: ...

    def pop_due(self) -> List[Any]: ...

    def peek_next(self) -> Optional[float]: ...

    def advance(self, until: Optional[float] = None) -> None: ...

    def restart(self) -> None: ...

    def set_deadline(self, max_run_s: Optional[float]) -> None: ...

    def expired(self) -> bool: ...

    def cadence(self, period_s: float) -> Cadence: ...


class _TickCadence:
    """Virtual cadence: fires when the tick counter hits the modulus —
    bit-identical to the old ``tick % every == 0`` gateway checks (fires at
    tick 0, then every ``every_ticks``)."""

    def __init__(self, clock: "VirtualClock", every_ticks: int):
        self._clock = clock
        self._every = max(1, int(every_ticks))

    def due(self) -> bool:
        return self._clock._tick % self._every == 0


class _WallCadence:
    """Wall cadence: fires whenever real time reaches the next due point
    (first call always fires, mirroring the tick-0 virtual behavior)."""

    def __init__(self, clock: "WallClock", period_s: float):
        self._clock = clock
        self._period = max(float(period_s), 0.0)
        self._next = clock.now()

    def due(self) -> bool:
        now = self._clock.now()
        if now + EPS >= self._next:
            self._next = now + self._period
            return True
        return False


class VirtualClock:
    """Deterministic step-driven clock: integer ticks of ``tick_s`` seconds.

    Event releases within one tick come back in SCHEDULE order (not release
    order): the pre-refactor gateway submitted transit-delayed stages by
    scanning its in-flight dict in insertion order every tick, so two events
    due in the same tick must fire in the order they were scheduled for runs
    to stay bit-identical.

    The run deadline can be set in seconds (``set_deadline``) or — for the
    deprecated ``max_ticks`` call path — in exact ticks
    (``set_deadline_ticks``), so legacy callers keep their precise cutoff.
    """

    name = "virtual"

    def __init__(self, tick_s: float = 0.05):
        self.tick_s = float(tick_s)
        self._tick = 0
        self._heap: List[tuple] = []          # (release_t, seq, payload)
        self._seq = 0
        self._max_tick: Optional[int] = None

    # ------------------------------------------------------------------ time
    def now(self) -> float:
        return self._tick * self.tick_s

    @property
    def tick(self) -> int:
        return self._tick

    def advance(self, until: Optional[float] = None) -> None:
        # virtual time is oblivious to wake-up hints: one advance = one tick
        self._tick += 1

    def restart(self) -> None:
        """No-op: virtual time is already workload-relative (tick 0 is the
        start of the run, not of clock construction)."""

    # ---------------------------------------------------------------- events
    def call_at(self, t: float, payload: Any) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (float(t), self._seq, payload))

    def peek_next(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop_due(self) -> List[Any]:
        now = self.now()
        due: List[tuple] = []
        while self._heap and self._heap[0][0] <= now + EPS:
            due.append(heapq.heappop(self._heap))
        # schedule order within the tick (see class docstring)
        due.sort(key=lambda e: e[1])
        return [payload for _, _, payload in due]

    # -------------------------------------------------------------- deadline
    def set_deadline(self, max_run_s: Optional[float]) -> None:
        self._max_tick = (None if max_run_s is None
                          else int(round(max_run_s / self.tick_s)))

    def set_deadline_ticks(self, max_ticks: Optional[int]) -> None:
        self._max_tick = None if max_ticks is None else int(max_ticks)

    @property
    def deadline_s(self) -> Optional[float]:
        return (None if self._max_tick is None
                else self._max_tick * self.tick_s)

    def expired(self) -> bool:
        return self._max_tick is not None and self._tick >= self._max_tick

    # --------------------------------------------------------------- cadence
    def cadence(self, period_s: float) -> Cadence:
        return _TickCadence(self, round(float(period_s) / self.tick_s))


class WallClock:
    """Real monotonic time. ``now()`` is seconds since construction;
    ``advance(until)`` sleeps until the requested wake-up (capped at
    :data:`MAX_WALL_SLEEP_S` so deadlines and arrivals are never starved);
    events release when wall time passes them, in release order.

    ``time_fn``/``sleep_fn`` are injectable for deterministic unit tests.
    """

    name = "wall"

    def __init__(self, time_fn: Callable[[], float] = time.monotonic,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self._time = time_fn
        self._sleep = sleep_fn
        self._t0 = self._time()
        self._heap: List[tuple] = []
        self._seq = 0
        self._deadline: Optional[float] = None

    # ------------------------------------------------------------------ time
    def now(self) -> float:
        return self._time() - self._t0

    def advance(self, until: Optional[float] = None) -> None:
        if until is None:
            return                 # caller did real work this pass: free-run
        delay = until - self.now()
        if delay > 0:
            self._sleep(min(delay, MAX_WALL_SLEEP_S))

    def restart(self) -> None:
        """Re-zero the epoch: wall time restarts at 0 NOW. The gateway
        calls this when a run begins, so pre-run work (fleet warmup, JIT
        compilation) is never billed to the measured serving window.
        Events still pending (e.g. stages left in transit when a previous
        run hit its deadline) keep their REMAINING delay: their release
        times are rebased onto the new epoch."""
        offset = self.now()
        if self._heap:
            self._heap = [(max(0.0, t - offset), seq, payload)
                          for t, seq, payload in self._heap]
            heapq.heapify(self._heap)
        self._t0 = self._time()

    # ---------------------------------------------------------------- events
    def call_at(self, t: float, payload: Any) -> None:
        self._seq += 1
        heapq.heappush(self._heap, (float(t), self._seq, payload))

    def peek_next(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop_due(self) -> List[Any]:
        now = self.now()
        due: List[Any] = []
        while self._heap and self._heap[0][0] <= now:
            due.append(heapq.heappop(self._heap)[2])
        return due

    # -------------------------------------------------------------- deadline
    def set_deadline(self, max_run_s: Optional[float]) -> None:
        self._deadline = None if max_run_s is None else float(max_run_s)

    @property
    def deadline_s(self) -> Optional[float]:
        return self._deadline

    def expired(self) -> bool:
        return self._deadline is not None and self.now() >= self._deadline

    # --------------------------------------------------------------- cadence
    def cadence(self, period_s: float) -> Cadence:
        return _WallCadence(self, period_s)


def make_clock(mode: str, tick_s: float) -> Clock:
    """Clock factory for ``GatewayConfig.clock`` ("virtual" | "wall")."""
    if mode == "virtual":
        return VirtualClock(tick_s=tick_s)
    if mode == "wall":
        return WallClock()
    raise ValueError(f"unknown clock mode {mode!r} "
                     "(expected 'virtual' or 'wall')")
