"""Deterministic fault-injection for the live serving plane.

A :class:`FaultPlan` is a scripted list of mid-run events — kill a worker,
degrade/restore a cross-cluster link, boot a replacement node — scheduled on
the gateway's own clock plane: ``FaultPlan.arm(gw)`` registers each event as
a callable payload via ``clock.call_at``, and the gateway fires it inside
``_fire_releases`` at the same boundary as transit releases. Under the
virtual clock the injection times are exact virtual seconds, so a faulted
run is as reproducible as a healthy one; under the wall clock the events
fire at real elapsed seconds and recovery rides the liveness plane
(heartbeat sweep in ``registry.py``, straggler demotion in
``distributed/fault.py``).

Recovery itself is entirely the existing machinery: a killed worker
surfaces as a typed ``WorkerDied`` -> ``_on_node_death`` -> evacuation and a
``NodeDeathEvent``; a replacement joins through ``register_node``. The plan
only decides *when* the world breaks, never *how* the gateway heals.
"""
from __future__ import annotations

import dataclasses
import os
import signal as _signal
from typing import Any, Callable, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class FaultEvent:
    """One scheduled disruption. ``at_s`` is run-relative (the plan is
    armed right after ``clock.restart()``). Subclasses implement
    ``fire(gw, now)`` and return a short human-readable outcome string for
    the plan's ``fired`` log."""
    at_s: float

    def fire(self, gw, now: float) -> str:  # pragma: no cover - interface
        raise NotImplementedError


@dataclasses.dataclass
class KillWorker(FaultEvent):
    """Kill node ``node_id`` abruptly. Worker backends with a local child
    process get a real SIGKILL (the transport EOF / heartbeat sweep then
    detects the death exactly like a production crash); remote socket
    workers (``proc is None``) get their connection torn down, which is the
    same wire-level signal. In-process runtimes have no process to kill, so
    the death is reported straight to the gateway — the virtual-clock path
    that keeps scenario sweeps deterministic."""
    node_id: int = 0

    def fire(self, gw, now: float) -> str:
        node = gw.fleet.get(self.node_id)
        if node is None:
            return f"kill node {self.node_id}: skipped (not in fleet)"
        proc = getattr(node, "proc", None)
        if proc is not None and proc.is_alive():
            os.kill(proc.pid, _signal.SIGKILL)
            return f"kill node {self.node_id}: SIGKILL pid {proc.pid}"
        conn = getattr(node, "_conn", None)
        if conn is not None:
            try:
                conn.close()
            except OSError:
                pass
            return f"kill node {self.node_id}: closed transport"
        gw._on_node_death(self.node_id, now,
                          cause="fault injection: killed")
        return f"kill node {self.node_id}: reported death (in-process)"


@dataclasses.dataclass
class DegradeLink(FaultEvent):
    """Inflate one cross-cluster link's RTT by ``factor`` (e.g. 50x models
    a congested or flapping WAN path; the fitness router sees the new cost
    on its next dispatch)."""
    src_cluster: int = 0
    dst_cluster: int = 1
    factor: float = 50.0

    def fire(self, gw, now: float) -> str:
        gw.degrade_link(self.src_cluster, self.dst_cluster, self.factor)
        return (f"degrade link {self.src_cluster}<->{self.dst_cluster} "
                f"x{self.factor:g}")


@dataclasses.dataclass
class RestoreLink(FaultEvent):
    """Return a degraded link to its nominal RTT."""
    src_cluster: int = 0
    dst_cluster: int = 1

    def fire(self, gw, now: float) -> str:
        gw.restore_link(self.src_cluster, self.dst_cluster)
        return f"restore link {self.src_cluster}<->{self.dst_cluster}"


@dataclasses.dataclass
class RegisterNode(FaultEvent):
    """Mid-run elasticity: boot a replacement (or scale-out) node and admit
    it to the serving fleet. ``factory`` builds the handle/runtime when the
    event fires — not at plan construction — so the replacement's boot cost
    lands inside the measured window, like a real autoscaler action."""
    factory: Optional[Callable[[], Any]] = None

    def fire(self, gw, now: float) -> str:
        if self.factory is None:
            return "register node: skipped (no factory)"
        nid = gw.register_node(self.factory())
        return f"register node {nid}"


@dataclasses.dataclass
class FaultPlan:
    """An ordered schedule of :class:`FaultEvent`s plus the log of what
    actually fired (``fired``: run-relative time, outcome string). Pass it
    to ``ClusterGateway.run(jobs, fault_plan=plan)``; a plan can be armed
    once per run."""
    events: Sequence[FaultEvent] = ()

    def __post_init__(self):
        self.fired: List[Tuple[float, str]] = []
        self._armed = False

    def arm(self, gw) -> None:
        if self._armed:
            raise RuntimeError("FaultPlan already armed — plans are "
                               "single-use (the fired log is per-run)")
        self._armed = True
        base = gw.clock.now()
        for ev in sorted(self.events, key=lambda e: e.at_s):
            self._schedule(gw, ev, base + ev.at_s)

    def _schedule(self, gw, ev: FaultEvent, release_t: float) -> None:
        def payload(now: float, _ev=ev):
            self.fired.append((now, _ev.fire(gw, now)))
        gw.clock.call_at(release_t, payload)
