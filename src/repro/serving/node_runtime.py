"""Node-level multi-model runtime: real model colocation on one device.

Holds a zoo of (small) models; weights move between DEVICE (jnp arrays) and
HOST (numpy) following the hierarchical residency manager — a Sleeping model
keeps its compiled executable cache (the CUDA-graph analogue: jax.jit cache
keyed by shapes survives offload) while its weights live in host RAM.
Exports the readiness / headroom signals (NodeSignal) the cross-cluster
scheduler consumes.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.predictor.cost_model import HardwareSpec, ModelProfile
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.coordination import (EngineInfo, EngineState,
                                             plan_degradation)
from repro.core.runtime.residency import HierarchicalResidency, ModelState
from repro.core.sched.fitness import NodeSignal
from repro.models.transformer import Model
from repro.serving.engine import Engine, Request
from repro.serving.kv_arena import KVArena


def _tree_bytes(tree) -> int:
    return sum(x.nbytes for x in jax.tree.leaves(tree))


class NodeRuntime:
    def __init__(self, node_id: int, cluster_id: int,
                 zoo: Dict[str, Model], host_params: Dict[str, Any],
                 hbm_budget: float = 2e9, max_slots: int = 4,
                 s_max: int = 256, ctx_bytes: int = 8 << 20,
                 page_tokens: int = 16, prefix_cache: bool = False,
                 prefix_cache_pages: int = 256,
                 max_batch_tokens: Optional[int] = None,
                 prefill_chunk_tokens: int = 0,
                 decode_horizon: int = 1):
        self.node_id = node_id
        self.cluster_id = cluster_id
        self.zoo = zoo
        self.host_params = host_params      # numpy trees (host tier)
        self.device_params: Dict[str, Any] = {}
        self.engines: Dict[str, Engine] = {}
        self.acc = MemoryAccountant(m_total=hbm_budget, m_other=16 << 20)
        # ONE physical paged-KV arena per node: every colocated engine's
        # pool grants map onto it 1:1 (§III.C spatial multiplexing)
        self.arena = KVArena(page_tokens=page_tokens)
        self.prefix_cfg = None
        if prefix_cache:
            from repro.serving.prefix_cache import PrefixCacheConfig
            self.prefix_cfg = PrefixCacheConfig(max_pages=prefix_cache_pages)
        self.ctx_bytes = ctx_bytes
        self.max_slots = max_slots
        self.s_max = s_max
        # engine iteration-scheduler knobs (chunked prefill / token budget),
        # forwarded to every colocated engine at activation
        self.max_batch_tokens = max_batch_tokens
        self.prefill_chunk_tokens = prefill_chunk_tokens
        self.decode_horizon = decode_horizon
        profiles = {
            name: ModelProfile(
                name=name, weight_bytes=_tree_bytes(host_params[name]),
                ctx_bytes=ctx_bytes,
                # dtype-aware: must match the engine pool's per-token charge
                # (reduced smoke configs run f32, production configs bf16)
                alpha_bytes_per_token=m.cfg.kv_bytes_per_token(
                    dtype_bytes=jax.numpy.dtype(m.cfg.dtype).itemsize),
                state_bytes=m.cfg.ssm_state_bytes(),
                prefill_flops_per_token=2.0 * m.cfg.active_param_count(),
                decode_bytes_per_token=2.0 * m.cfg.active_param_count(),
                hw=HardwareSpec())
            for name, m in zoo.items()}
        self.profiles = profiles
        self.residency = HierarchicalResidency(
            profiles, c_gpu=hbm_budget * 0.8, c_cpu=64e9, c_disk=1e12)
        # host tier is where everything starts
        for name in zoo:
            self.residency.state[name] = ModelState.CPU
            self.residency.lru["cpu"][name] = profiles[name].weight_bytes

    # ------------------------------------------------------------ residency
    def activate(self, name: str) -> float:
        """Make `name` servable; returns measured activation seconds."""
        t0 = time.perf_counter()
        # models with ANY queued work are in-flight: evicting one whose
        # requests are still waiting for admission would strand them (step()
        # skips off-device engines)
        self.residency.pinned = {m for m, e in self.engines.items()
                                 if e.active or e.waiting}
        ok, _ = self.residency.ensure_gpu(name)
        if not ok:
            raise RuntimeError(f"cannot activate {name}")
        # apply evictions the residency manager decided
        for m, st in self.residency.state.items():
            if st in (ModelState.SLEEPING, ModelState.CPU) \
                    and m in self.device_params:
                self._offload(m)
        if name not in self.device_params:
            self.device_params[name] = jax.tree.map(
                jax.device_put, self.host_params[name])
            self.acc.register_weights(
                name, self.profiles[name].weight_bytes)
            self.acc.register_context(name, self.ctx_bytes)
        if name not in self.engines:
            self.engines[name] = Engine(
                self.zoo[name], self.device_params[name], self.acc,
                max_slots=self.max_slots, s_max=self.s_max,
                arena=self.arena, prefix_cache=self.prefix_cfg,
                prefix_ns=name,
                max_batch_tokens=self.max_batch_tokens,
                prefill_chunk_tokens=self.prefill_chunk_tokens,
                decode_horizon=self.decode_horizon)
        else:
            self.engines[name].params = self.device_params[name]
        return time.perf_counter() - t0

    def _offload(self, name: str) -> None:
        """Device -> host (weights only; jit executable cache survives —
        that is what makes re-activation cheap for Sleeping models). The
        engine's KV — arena pages, block tables and the dense state cache —
        is freed and de-accounted here: an offloaded model holds no silent
        device-resident KV (leak fix)."""
        eng = self.engines.get(name)
        if eng is not None:
            eng.release_kv()
        self.device_params.pop(name, None)
        self.acc.unregister_weights(name)
        if self.residency.state[name] is ModelState.CPU:
            self.acc.unregister_context(name)

    def sleep(self, name: str) -> None:
        self.residency.sleep(name)
        self._offload(name)

    # -------------------------------------------------------------- serving
    def submit(self, model: str, req: Request) -> None:
        if model not in self.device_params:
            self.activate(model)
        self.engines[model].submit(req)

    def preempt(self, model: str, req_id: int) -> Optional[Request]:
        """Boundary-preempt a request on this node (waiting or active);
        returns the withdrawn Request (partial output discarded) or None."""
        eng = self.engines.get(model)
        return None if eng is None else eng.evict(req_id)

    def t_act(self, model: str) -> float:
        """Estimated activation latency (no side effects) — the T_act of
        Eq. 6 that the cross-cluster router consumes."""
        return self.residency.activation_latency(model)

    # ----------------------------------------------- admission (Alg. 2 aware)
    def _busy_models(self) -> set:
        return {m for m, e in self.engines.items() if e.active or e.waiting}

    def can_admit(self, r_need: float, model: Optional[str] = None) -> bool:
        """Eviction-aware KV admission feasibility (mirrors SimNode):
        everything except in-flight models' weights and contexts can be
        reclaimed by degradation levels 1-2 before the stage lands."""
        extra = 0.0
        if model is not None:
            if model not in self.acc.weights:
                extra += self.profiles[model].weight_bytes
            if model not in self.acc.ctx:
                extra += self.profiles[model].ctx_bytes
        if self.acc.can_admit(r_need + extra):
            return True
        if model is None:
            return False
        active = self._busy_models() | {model}
        floor = sum(self.profiles[m].weight_bytes + self.profiles[m].ctx_bytes
                    for m in active)
        # in-flight engines also keep their dense state caches resident
        floor += sum(e._state_bytes for m2, e in self.engines.items()
                     if m2 in active)
        return (floor + self.acc.m_kv + self.acc.m_other + r_need
                <= self.acc.m_total)

    def degradation_cost(self, r_need: float) -> Optional[float]:
        """C_deg for admitting r_need via Algorithm 2 (None = impossible) —
        the live counterpart of SimNode.degradation_cost, built from the
        real engines' in-flight state."""
        shortfall = r_need - self.acc.headroom
        if shortfall <= 0:
            return 0.0
        busy = self._busy_models()
        engines = []
        for m in self.residency.warm_set():
            st = self.residency.state[m]
            eng = self.engines.get(m)
            kv_tokens = (sum(len(r.tokens) + len(r.out)
                             for r in eng.active.values()) if eng else 0)
            prof = self.profiles[m]
            engines.append(EngineInfo(
                model=m,
                state=(EngineState.ACTIVE if m in busy else
                       EngineState.IDLE if st is ModelState.RUNNING
                       else EngineState.SLEEPING),
                weight_bytes=prof.weight_bytes,
                ctx_bytes=prof.ctx_bytes,
                kv_bytes=float((eng.alpha if eng else 0) * kv_tokens),
                kv_tokens=kv_tokens,
                decode_tok_per_s=1.0 / max(prof.t_decode, 1e-9)))
        plan = plan_degradation(shortfall, engines,
                                next(iter(self.profiles.values())).hw)
        return None if plan is None else plan.c_deg

    def make_room(self, r_need: float) -> None:
        """Degradation levels 0-2 (Algorithm 2's cheap prefix) on the live
        node: trim cached-but-unreferenced prefix pages first, then sleep
        idle engines, then drop sleeping warm contexts, until r_need fits.
        In-flight engines are never touched."""
        idx = self.arena.prefix_index
        if idx is not None:
            while idx.entries and not self.acc.can_admit(r_need):
                if not idx.trim(8):                   # level 0
                    break
        busy = self._busy_models()
        for m in list(self.residency.lru["gpu"]):
            if self.acc.can_admit(r_need):
                return
            if m not in busy and self.residency.state[m] is ModelState.RUNNING:
                self.sleep(m)                         # level 1
        for m, st in list(self.residency.state.items()):
            if self.acc.can_admit(r_need):
                return
            if m not in busy and st is ModelState.SLEEPING:
                self.residency.demote_context(m)      # level 2
                self.acc.unregister_context(m)

    def has_work(self) -> bool:
        """True while any colocated engine has waiting or active requests —
        the free-running worker loop and the wall-clock gateway step/poll
        only nodes for which this holds."""
        return any(e.waiting or e.active for e in self.engines.values())

    def step(self) -> Dict[str, list]:
        out = {}
        for name, eng in self.engines.items():
            if (eng.waiting or eng.active) and name not in self.device_params:
                self.activate(name)   # self-heal: offloaded with queued work
            if name in self.device_params and (eng.waiting or eng.active):
                eng.step()
            if eng.finished:
                out[name] = eng.finished[:]
                eng.finished.clear()
        return out

    # -------------------------------------------------------------- signals
    def kv_overcommit_ratio(self) -> float:
        """Live counterpart of Table V's overcommit: total virtual KV the
        colocated engines advertise over the PEAK physical KV ever mapped in
        the shared arena. > 1 means spatial multiplexing is really happening
        (the engines together promise more KV than was ever resident).
        0.0 until any KV was physically mapped (ratio undefined)."""
        if self.arena.peak_mapped_bytes <= 0:
            return 0.0
        virt = sum(e.pool.virtual_total() for e in self.engines.values())
        return virt / self.arena.peak_mapped_bytes

    def kv_stats(self) -> Dict[str, float]:
        """Arena/overcommit snapshot consumed by gateway end-of-run metrics
        — one picklable dict so worker processes report it in a single
        round trip."""
        out = {"n_engines": len(self.engines),
               "kv_overcommit_ratio": self.kv_overcommit_ratio(),
               "arena_peak_pages": int(self.arena.peak_mapped_pages),
               "arena_utilization": float(self.arena.utilization()),
               "pages_aliased": int(self.arena.pages_aliased),
               "cow_copies": int(self.arena.cow_copies),
               # iteration-scheduler telemetry, summed over engines
               "engine_prefill_tokens": sum(
                   e.stat_prefill_tokens for e in self.engines.values()),
               "engine_decode_tokens": sum(
                   e.stat_decode_tokens for e in self.engines.values()),
               "engine_prefill_compiles": sum(
                   e.prefill_compiles for e in self.engines.values()),
               "engine_fused_steps": sum(
                   e.stat_fused_steps for e in self.engines.values()),
               "engine_steps": sum(
                   e.stat_steps for e in self.engines.values()),
               # decode-horizon telemetry: fused multi-token launches and
               # host round-trips (one per horizon launch vs one per token)
               "engine_horizon_steps": sum(
                   e.stat_horizon_steps for e in self.engines.values()),
               "engine_decode_syncs": sum(
                   e.stat_decode_syncs for e in self.engines.values())}
        if self.arena.prefix_index is not None:
            out.update(self.arena.prefix_index.stats())
        return out

    @property
    def page_tokens(self) -> int:
        return self.arena.page_tokens

    def signal(self) -> NodeSignal:
        warm = {m: self.residency.activation_latency(m)
                for m in self.residency.warm_set()}
        qd = float(np.mean([len(e.waiting) for e in self.engines.values()])
                   ) if self.engines else 0.0
        return NodeSignal(node_id=self.node_id, cluster_id=self.cluster_id,
                          headroom=self.acc.headroom, queue_delay_s=qd,
                          warm_models=warm, total_hbm=self.acc.m_total,
                          prefix_digests=self.arena.prefix_digest_summary())
