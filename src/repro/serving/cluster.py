"""Live cluster topology: fleets of real ``NodeRuntime`` engines spread
across simulated-RTT clusters, plus the trace -> live-workload adapter.

This is the prototype-experiment substrate of the paper (§IV "prototype"):
every node holds the same (tiny, structurally faithful) model zoo and real
JAX engines; cross-cluster effects (RTT, cold starts) enter through the
gateway's deterministic virtual clock rather than wall-clock sleeps.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.core.predictor.features import StageObservation
from repro.core.topology import DEFAULT_RTT
from repro.data.tracegen import JobRecord
from repro.models import build_model
from repro.serving.node_runtime import NodeRuntime

# default live zoo: three distinct families colocated per node (attention,
# code-tuned attention, SSM) — the Table-IV colocation regime in miniature
DEFAULT_ZOO = ("qwen3-8b", "starcoder2-15b", "mamba2-2.7b")


@dataclasses.dataclass
class NodeSpec:
    cluster_id: int
    hbm_budget: float = 1.2e9
    max_slots: int = 4
    s_max: int = 64
    # cross-stage prefix-cache plane (off by default: disabled fleets stay
    # bit-identical to pre-prefix-cache behavior)
    prefix_cache: bool = False
    prefix_cache_pages: int = 256
    # engine iteration scheduler (0 = monolithic prefill, bit-identical to
    # pre-chunking behavior; > 0 streams prompts in fixed-width chunks
    # fused with decode, budgeted by max_batch_tokens per iteration)
    max_batch_tokens: Optional[int] = None
    prefill_chunk_tokens: int = 0
    # decode horizon (1 = one host sync per decode iteration, bit-identical
    # to pre-horizon behavior; H > 1 fuses up to H decode iterations into
    # one jitted on-device loop with a single host sync per launch)
    decode_horizon: int = 1


@dataclasses.dataclass
class ClusterSpec:
    """Fleet description consumed by ``build_fleet``."""
    nodes: Tuple[NodeSpec, ...] = (NodeSpec(0), NodeSpec(0, hbm_budget=0.8e9),
                                   NodeSpec(1))
    rtt_s: np.ndarray = dataclasses.field(
        default_factory=lambda: DEFAULT_RTT.copy())
    model_names: Tuple[str, ...] = DEFAULT_ZOO

    @property
    def n_clusters(self) -> int:
        return int(max(n.cluster_id for n in self.nodes)) + 1


def build_zoo(model_names: Sequence[str] = DEFAULT_ZOO, seed: int = 1
              ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Tiny real models (reduced configs) + host-tier numpy parameter trees.
    The host trees are shared by every node of the fleet (a model registry),
    exactly as weights would be fetched from common storage."""
    zoo, host = {}, {}
    for i, name in enumerate(model_names):
        cfg = get_config(name).reduced()
        m = build_model(cfg)
        zoo[name] = m
        host[name] = jax.tree.map(np.asarray,
                                  m.init(jax.random.PRNGKey(seed + i)))
    return zoo, host


def worker_specs(spec: ClusterSpec, seed: int = 1,
                 worker_xla_flags: Optional[str] = None) -> List[Any]:
    """The picklable per-node ``WorkerSpec`` list for a cluster spec —
    what both worker backends ship to their children, and what
    ``connect_fleet`` sends to standalone remote workers."""
    from repro.serving.worker import WorkerSpec
    return [WorkerSpec(node_id=nid, cluster_id=ns.cluster_id,
                       model_names=tuple(spec.model_names),
                       hbm_budget=ns.hbm_budget, max_slots=ns.max_slots,
                       s_max=ns.s_max, seed=seed,
                       prefix_cache=ns.prefix_cache or None,
                       prefix_cache_pages=(ns.prefix_cache_pages
                                           if ns.prefix_cache else None),
                       max_batch_tokens=ns.max_batch_tokens,
                       prefill_chunk_tokens=(ns.prefill_chunk_tokens
                                             or None),
                       decode_horizon=(ns.decode_horizon
                                       if ns.decode_horizon > 1 else None),
                       xla_flags=worker_xla_flags)
            for nid, ns in enumerate(spec.nodes)]


def build_fleet(spec: Optional[ClusterSpec] = None,
                zoo: Optional[Dict[str, Any]] = None,
                host: Optional[Dict[str, Any]] = None,
                seed: int = 1, backend: str = "inproc",
                worker_xla_flags: Optional[str] = None,
                worker_addresses: Optional[Sequence[Any]] = None
                ) -> List[Any]:
    """Instantiate the fleet; node ids are positional.

    ``backend="inproc"`` (default) returns in-process ``NodeRuntime``
    objects; ``backend="process"`` spawns one worker process per node and
    returns ``NodeHandle`` proxies (each child builds its own zoo from the
    same ``model_names`` + ``seed``, so the fleets are numerically
    identical — ``zoo``/``host`` are ignored there); ``backend="socket"``
    speaks the same protocol over the framed TCP transport — localhost
    children by default, or, when ``worker_addresses`` gives one
    "host:port" per node, workers already listening elsewhere (started
    with ``python -m repro.serving.worker --listen``).
    ``worker_xla_flags`` (worker backends only) is appended to each child's
    ``XLA_FLAGS`` before its XLA client forms — an operator knob for wall-
    clock fleets (e.g. pin workers single-threaded on hosts where process
    thread pools outnumber cores; measure first — on some hosts the pool
    wins). Leave it None for virtual-clock runs, whose bit-identical
    parity is stated for unmodified child numerics."""
    spec = spec or ClusterSpec()
    if worker_addresses is not None and backend != "socket":
        raise ValueError("worker_addresses requires backend='socket'")
    if backend in ("process", "socket"):
        from repro.serving.worker import connect_fleet, spawn_fleet
        specs = worker_specs(spec, seed=seed,
                             worker_xla_flags=worker_xla_flags)
        if worker_addresses is not None:
            return connect_fleet(worker_addresses, specs)
        return spawn_fleet(specs, backend=backend)
    if backend != "inproc":
        raise ValueError(f"unknown node backend {backend!r} "
                         "(expected 'inproc', 'process' or 'socket')")
    if zoo is None or host is None:
        zoo, host = build_zoo(spec.model_names, seed=seed)
    fleet = []
    for nid, ns in enumerate(spec.nodes):
        fleet.append(NodeRuntime(nid, ns.cluster_id, zoo, host,
                                 hbm_budget=ns.hbm_budget,
                                 max_slots=ns.max_slots, s_max=ns.s_max,
                                 prefix_cache=ns.prefix_cache,
                                 prefix_cache_pages=ns.prefix_cache_pages,
                                 max_batch_tokens=ns.max_batch_tokens,
                                 prefill_chunk_tokens=ns.prefill_chunk_tokens,
                                 decode_horizon=ns.decode_horizon))
    return fleet


# ---------------------------------------------------------------------------
# Trace adapter: simulator JobRecords -> live jobs with real token prompts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LiveStage:
    stage_id: int
    job_id: int
    deps: List[int]
    obs: StageObservation
    interactive: bool
    tokens: List[int]             # real prompt token ids
    max_new: int                  # decode budget (ground-truth len, capped)
    nominal_len: int = 0          # uncapped trace-scale output length; the
                                  # calibration target for L_hat (0 => max_new)


@dataclasses.dataclass
class LiveJob:
    job_id: int
    app: str
    interactive: bool
    arrival_s: float
    stages: List[LiveStage]
    deadline_s: float = 0.0       # filled by the gateway's SLO profiler


def _block_tokens(key: str, n: int, vocab: int) -> List[int]:
    """Token ids of a named prompt block, derived from the key ALONE (an
    rng seeded from the key's hash) — equal keys materialize to identical
    tokens in any job/stage, which is precisely the shared-prefix property
    the cross-stage prefix cache exploits. Does not touch the trace-level
    rng, so classic (block-free) traces stay byte-identical."""
    h = hashlib.blake2b(key.encode(), digest_size=8).digest()
    r = np.random.default_rng(int.from_bytes(h, "big"))
    return [int(x) for x in r.integers(0, vocab, n)]


def jobs_from_trace(trace_jobs: Sequence[JobRecord], vocab: int = 512,
                    prompt_cap: int = 16, gen_cap: int = 16,
                    n_clusters: int = 3, seed: int = 0) -> List[LiveJob]:
    """Instantiate real token payloads for a generated trace. Prompt/output
    lengths are capped so tiny smoke models execute quickly; the ORIGINAL
    observation (with its uncapped prompt_len and semantic text) is kept, so
    the predictor and router see the workload the trace describes.

    Stages carrying ``prompt_blocks`` (team traces) get their tokens from
    the named blocks instead of the shared rng: block-structured prompts
    with identical leading blocks share identical leading tokens."""
    rng = np.random.default_rng(seed)
    out: List[LiveJob] = []
    for j in trace_jobs:
        stages = []
        for s in j.stages:
            obs = s.obs
            if obs.src_cluster >= n_clusters:
                obs = dataclasses.replace(obs,
                                          src_cluster=obs.src_cluster
                                          % n_clusters)
            blocks = getattr(s, "prompt_blocks", None)
            if blocks:
                tokens: List[int] = []
                for key, n in blocks:
                    tokens += _block_tokens(key, n, vocab)
            else:
                p = int(np.clip(s.obs.prompt_len // 32, 4, prompt_cap))
                tokens = list(rng.integers(0, vocab, p))
            stages.append(LiveStage(
                stage_id=s.stage_id, job_id=j.job_id, deps=list(s.deps),
                obs=obs, interactive=s.interactive,
                tokens=tokens,
                max_new=int(np.clip(s.true_len // 16, 4, gen_cap)),
                nominal_len=int(s.true_len)))
        out.append(LiveJob(job_id=j.job_id, app=j.app,
                           interactive=j.interactive,
                           arrival_s=j.arrival_s, stages=stages))
    return out
