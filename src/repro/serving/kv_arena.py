"""Physical paged KV arena: the array-backed store behind the elastic
virtual KV pool (§III.C spatial multiplexing, made memory-honest).

One :class:`KVArena` per ``NodeRuntime`` owns the K/V page storage every
colocated engine decodes from. Storage is organised into *planes* — one pair
of ``[n_layers, n_rows, page_tokens, Hkv, hd]`` K and V arrays per distinct
KV geometry — so models with identical per-token KV shape (e.g. two reduced
dense configs) physically interleave their pages in the same arrays, which is
what makes multi-model co-location spatially multiplexed rather than
partitioned.

The arena itself never decides admission. Every alloc / grow / free / evict
flows through the engine's :class:`~repro.core.runtime.kv_pool.VirtualKVPool`
(virtual budgets, accountant-checked physical growth), and the per-engine
:class:`ModelKVBinding` mirrors the pool's page grants 1:1: each granted pool
page is pinned to exactly one plane row for as long as it stays mapped, and
``reclaim()`` returns rows to the plane exactly when the pool unmaps pages
back to the accountant. Admission and Algorithm-2 degradation therefore keep
their existing semantics while now governing real storage.

Row 0 of every plane is a reserved *null row*: engines point idle decode
slots at it (reads and writes land there harmlessly), so it is never granted
to a sequence.

Sizing knobs: ``page_tokens`` (tokens per page, must match the pools that
bind to the arena) and ``init_rows`` (initial plane capacity; capacity grows
geometrically so jitted decode signatures stay stable between doublings).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.runtime.kv_pool import VirtualKVPool

NULL_ROW = 0


@dataclasses.dataclass(frozen=True)
class PlaneSpec:
    """KV geometry of one arena plane (the plane-sharing key)."""
    n_layers: int          # stacked self-attention layers
    page_tokens: int
    n_kv_heads: int
    head_dim: int
    dtype: str             # canonical dtype name (jnp.dtype(...).name)

    @property
    def row_bytes(self) -> int:
        """Physical bytes of one K+V row (= one page across all layers)."""
        return (2 * self.n_layers * self.page_tokens * self.n_kv_heads
                * self.head_dim * jnp.dtype(self.dtype).itemsize)


class ArenaPlane:
    """One geometry's physical page store: K/V arrays + a free-row list."""

    def __init__(self, spec: PlaneSpec, init_rows: int = 8):
        self.spec = spec
        n = max(2, init_rows)              # row 0 is the reserved null row
        self.k = jnp.zeros(self._shape(n), spec.dtype)
        self.v = jnp.zeros(self._shape(n), spec.dtype)
        self.free_rows: List[int] = list(range(n - 1, 0, -1))
        self.refs: Dict[int, int] = {}     # live row -> reference count

    def _shape(self, n_rows: int):
        s = self.spec
        return (s.n_layers, n_rows, s.page_tokens, s.n_kv_heads, s.head_dim)

    @property
    def n_rows(self) -> int:
        return self.k.shape[1]

    def take_row(self) -> int:
        if not self.free_rows:
            self._grow()
        row = self.free_rows.pop()
        self.refs[row] = 1
        return row

    def share_row(self, row: int) -> None:
        """Add a reference to a live row (prefix alias or index pin)."""
        assert row != NULL_ROW and row in self.refs
        self.refs[row] += 1

    def drop_row(self, row: int) -> None:
        """Release one reference; the row returns to the free list at zero."""
        assert row != NULL_ROW
        self.refs[row] -= 1
        if self.refs[row] == 0:
            del self.refs[row]
            self.free_rows.append(row)

    # old single-owner name kept: with refcounts, give == drop one reference
    give_row = drop_row

    def copy_row(self, src: int) -> int:
        """Copy-on-write: materialise a private copy of a shared row."""
        dst = self.take_row()
        self.k = self.k.at[:, dst].set(self.k[:, src])
        self.v = self.v.at[:, dst].set(self.v[:, src])
        return dst

    def _grow(self) -> None:
        """Double capacity (geometric: keeps decode retraces logarithmic)."""
        old = self.n_rows
        new = old * 2
        self.k = jnp.zeros(self._shape(new), self.spec.dtype).at[:, :old].set(self.k)
        self.v = jnp.zeros(self._shape(new), self.spec.dtype).at[:, :old].set(self.v)
        self.free_rows.extend(range(new - 1, old - 1, -1))

    def write_prompt(self, n_layers: int, rows: np.ndarray,
                     k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Scatter a prompt's KV into this plane.

        ``k``/``v`` are ``[n_layers, P, Hkv, hd]`` (layer-stacked prefill
        cache); ``rows`` the plane rows of the sequence's first
        ``ceil(P/page_tokens)`` pages.
        """
        page = self.spec.page_tokens
        P = k.shape[1]
        n = -(-P // page)
        pad = n * page - P
        if pad:
            padding = ((0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, padding)
            v = jnp.pad(v, padding)
        shape = (n_layers, n, page) + k.shape[2:]
        idx = jnp.asarray(rows[:n], jnp.int32)
        self.k = self.k.at[:n_layers, idx].set(
            k.reshape(shape).astype(self.k.dtype))
        self.v = self.v.at[:n_layers, idx].set(
            v.reshape(shape).astype(self.v.dtype))

    def write_prompt_at(self, n_layers: int, rows: np.ndarray,
                        k: jnp.ndarray, v: jnp.ndarray,
                        start_off: int) -> None:
        """Scatter suffix KV starting mid-page.

        ``rows`` covers the pages from the one containing the first suffix
        token; ``start_off`` is that token's offset within it. The partial
        first page is written in place (its row must already be private),
        full pages after it go through :meth:`write_prompt`.
        """
        page = self.spec.page_tokens
        if start_off:
            m = min(page - start_off, k.shape[1])
            r = int(rows[0])
            self.k = self.k.at[:n_layers, r, start_off:start_off + m].set(
                k[:, :m].astype(self.k.dtype))
            self.v = self.v.at[:n_layers, r, start_off:start_off + m].set(
                v[:, :m].astype(self.v.dtype))
            k, v, rows = k[:, m:], v[:, m:], rows[1:]
        if k.shape[1]:
            self.write_prompt(n_layers, rows, k, v)


class ModelKVBinding:
    """The 1:1 mirror between one engine's pool grants and arena rows.

    Every pool page id maps to exactly one plane row from the moment it is
    granted until the pool unmaps it (``reclaim``). Models with no
    self-attention KV (pure SSM) bind with ``plane=None``: pool accounting
    still flows (their recurrent state is charged elsewhere) but no rows are
    held.
    """

    def __init__(self, arena: "KVArena", name: str, pool: VirtualKVPool,
                 plane: Optional[ArenaPlane], n_layers: int, s_max: int):
        self.arena = arena
        self.name = name
        self.pool = pool
        self.plane = plane
        self.n_layers = n_layers
        self.bt_width = max(1, -(-s_max // arena.page_tokens))
        self.row_of: Dict[int, int] = {}       # pool page id -> plane row

    @property
    def paged(self) -> bool:
        return self.plane is not None

    # -------------------------------------------------------------- grants
    def alloc_seq(self, seq_id: int, model: str, tokens: int,
                  alias_rows: Optional[List[int]] = None) -> bool:
        if not self.pool.alloc_seq(seq_id, model, tokens):
            return False
        self._map(seq_id, alias_rows)
        return True

    def ensure_tokens(self, seq_id: int, total_tokens: int) -> bool:
        """Grow the sequence's page span to cover ``total_tokens``."""
        s = self.pool.seqs[seq_id]
        if total_tokens > s.tokens:
            if not self.pool.extend_seq(seq_id, total_tokens - s.tokens):
                return False
            self._map(seq_id)
        return True

    def _map(self, seq_id: int,
             alias_rows: Optional[List[int]] = None) -> None:
        if self.plane is not None:
            for i, p in enumerate(self.pool.seqs[seq_id].pages):
                if p in self.row_of:
                    continue
                if alias_rows is not None and i < len(alias_rows):
                    # prefix-cache hit: share the existing row (no alloc)
                    self.plane.share_row(alias_rows[i])
                    self.row_of[p] = alias_rows[i]
                    self.arena.pages_aliased += 1
                else:
                    self.row_of[p] = self.plane.take_row()
        self.arena.note_usage()

    def make_private(self, seq_id: int, page_idx: int) -> bool:
        """Copy-on-write: give page ``page_idx`` of the sequence a private
        row if its current row is shared. Returns True when a copy ran."""
        if self.plane is None:
            return False
        pages = self.pool.seqs[seq_id].pages
        if page_idx >= len(pages):
            return False
        p = pages[page_idx]
        row = self.row_of[p]
        if self.plane.refs.get(row, 0) <= 1:
            return False
        new = self.plane.copy_row(row)
        self.plane.drop_row(row)
        self.row_of[p] = new
        self.arena.cow_copies += 1
        return True

    # --------------------------------------------------------------- frees
    def free_seq(self, seq_id: int) -> None:
        """Release a sequence's pages to the pool, then unmap (elastic
        shrink): rows return to the plane exactly when the pool returns the
        bytes to the accountant."""
        self.pool.free_seq(seq_id)
        self.reclaim()

    def reclaim(self) -> None:
        if self.plane is not None:
            for p in self.pool.free_pages:
                row = self.row_of.pop(p, None)
                if row is not None:
                    self.plane.give_row(row)
        self.pool.reclaim_unmapped()
        self.arena.note_usage()

    def release_all(self) -> None:
        for sid in list(self.pool.seqs):
            self.pool.free_seq(sid)
        self.reclaim()

    # --------------------------------------------------------------- views
    def token_capacity(self, seq_id: int) -> int:
        """Tokens the sequence's CURRENT page grant can hold — the horizon
        pre-grant reads this to cap a launch's emission budget to what is
        already granted when the pool refuses further extension (page-
        granular truncation backpressure, decided on host)."""
        return len(self.pool.seqs[seq_id].pages) * self.pool.page_tokens

    def seq_rows(self, seq_id: int) -> List[int]:
        return [self.row_of[p] for p in self.pool.seqs[seq_id].pages]

    def row_table(self, seq_id: int) -> np.ndarray:
        """Block table of one sequence, padded with the null row."""
        out = np.full(self.bt_width, NULL_ROW, np.int32)
        rows = self.seq_rows(seq_id)
        assert len(rows) <= self.bt_width, (len(rows), self.bt_width)
        out[:len(rows)] = rows
        return out

    def write_prompt(self, seq_id: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        if self.plane is not None:
            rows = np.asarray(self.seq_rows(seq_id), np.int32)
            self.plane.write_prompt(self.n_layers, rows, k, v)

    def write_prompt_at(self, seq_id: int, k: jnp.ndarray, v: jnp.ndarray,
                        start_tok: int) -> None:
        """Scatter suffix KV for tokens ``start_tok..`` of the sequence."""
        if self.plane is not None:
            page = self.arena.page_tokens
            rows = np.asarray(self.seq_rows(seq_id)[start_tok // page:],
                              np.int32)
            self.plane.write_prompt_at(self.n_layers, rows, k, v,
                                       start_tok % page)

    # ----------------------------------------------------------- invariant
    def check_mirror(self) -> bool:
        """Pool<->arena mirror invariant: every granted page maps to a live
        non-null row, and nothing else is mapped. Rows may be shared across
        mappings (prefix aliases) — reference counts are reconciled at the
        arena level against binding maps plus prefix-index pins."""
        if self.plane is None:
            return not self.row_of
        pages: set = set()
        for s in self.pool.seqs.values():
            for p in s.pages:
                if self.row_of.get(p, NULL_ROW) == NULL_ROW:
                    return False
                pages.add(p)
        # pages freed to the pool but not yet reclaimed keep their rows
        for p in self.pool.free_pages:
            row = self.row_of.get(p)
            if row is not None:
                if row == NULL_ROW:
                    return False
                pages.add(p)
        return set(self.row_of) == pages


class KVArena:
    """Node-level physical paged KV store shared by all colocated engines."""

    def __init__(self, page_tokens: int = 16, init_rows: int = 8):
        self.page_tokens = page_tokens
        self.init_rows = init_rows
        self.planes: Dict[PlaneSpec, ArenaPlane] = {}
        self.bindings: Dict[str, ModelKVBinding] = {}
        self.peak_mapped_pages = 0
        self.peak_mapped_bytes = 0.0
        self.peak_rows = 0
        self.prefix_index = None           # set by enable_prefix_cache
        self.pages_aliased = 0             # pages granted without allocation
        self.cow_copies = 0                # shared rows privatised on write

    def enable_prefix_cache(self, accountant, cfg=None):
        """Attach (idempotently) the node-wide prefix index to this arena."""
        from repro.serving.prefix_cache import PrefixCacheConfig, PrefixIndex
        if self.prefix_index is None:
            self.prefix_index = PrefixIndex(self, accountant,
                                            cfg or PrefixCacheConfig())
        return self.prefix_index

    def prefix_digest_summary(self) -> Tuple[str, ...]:
        return self.prefix_index.summary() if self.prefix_index else ()

    def register(self, name: str, pool: VirtualKVPool, s_max: int,
                 n_layers: int, n_kv_heads: int, head_dim: int,
                 dtype) -> ModelKVBinding:
        """Bind one engine's pool to the arena. ``n_layers == 0`` means the
        model holds no pageable self-attention KV (accounting-only binding)."""
        assert pool.page_tokens == self.page_tokens, \
            (pool.page_tokens, self.page_tokens)
        if name in self.bindings:
            raise ValueError(f"model {name!r} already bound to this arena")
        plane = None
        if n_layers > 0:
            spec = PlaneSpec(n_layers=n_layers, page_tokens=self.page_tokens,
                             n_kv_heads=n_kv_heads, head_dim=head_dim,
                             dtype=jnp.dtype(dtype).name)
            plane = self.planes.get(spec)
            if plane is None:
                plane = self.planes[spec] = ArenaPlane(spec, self.init_rows)
        b = ModelKVBinding(self, name, pool, plane, n_layers, s_max)
        self.bindings[name] = b
        return b

    # ------------------------------------------------------------- metrics
    def mapped_pages(self) -> int:
        return sum(b.pool.n_pages for b in self.bindings.values())

    def mapped_bytes(self) -> float:
        return sum(b.pool.n_pages * b.pool.page_bytes
                   for b in self.bindings.values())

    def mapped_rows(self) -> int:
        return sum(len(b.row_of) for b in self.bindings.values())

    def capacity_rows(self) -> int:
        return sum(p.n_rows - 1 for p in self.planes.values())

    def capacity_bytes(self) -> float:
        return sum((p.n_rows - 1) * p.spec.row_bytes
                   for p in self.planes.values())

    def utilization(self) -> float:
        """Peak mapped rows over allocated plane capacity."""
        cap = self.capacity_rows()
        return self.peak_rows / cap if cap else 0.0

    def note_usage(self) -> None:
        self.peak_mapped_pages = max(self.peak_mapped_pages,
                                     self.mapped_pages())
        self.peak_mapped_bytes = max(self.peak_mapped_bytes,
                                     self.mapped_bytes())
        self.peak_rows = max(self.peak_rows, self.mapped_rows())

    def stats(self) -> Dict[str, float]:
        return {
            "planes": len(self.planes),
            "page_tokens": self.page_tokens,
            "mapped_pages": self.mapped_pages(),
            "mapped_rows": self.mapped_rows(),
            "capacity_rows": self.capacity_rows(),
            "capacity_bytes": self.capacity_bytes(),
            "peak_mapped_pages": self.peak_mapped_pages,
            "peak_mapped_bytes": self.peak_mapped_bytes,
            "utilization": round(self.utilization(), 4),
            "pages_aliased": self.pages_aliased,
            "cow_copies": self.cow_copies,
        }

    def check_mirror(self) -> bool:
        if not all(b.check_mirror() for b in self.bindings.values()):
            return False
        # plane-level: the refcount of every live row equals its binding
        # mappings plus prefix-index pins, and live + free rows exactly tile
        # each plane (minus the null row).
        for spec, plane in self.planes.items():
            expect: Counter = Counter()
            for b in self.bindings.values():
                if b.plane is plane:
                    expect.update(b.row_of.values())
            if self.prefix_index is not None:
                expect.update(self.prefix_index.row_pins(plane))
            if NULL_ROW in expect:
                return False
            if dict(plane.refs) != dict(expect):
                return False
            if set(plane.free_rows) & set(plane.refs):
                return False
            if len(plane.free_rows) + len(plane.refs) != plane.n_rows - 1:
                return False
        return True
