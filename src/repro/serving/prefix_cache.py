"""Cross-stage prefix-cache plane: content-hashed KV pages with refcounts.

Multi-agent workflows re-send shared context on every stage — the team
system prompt, the per-role template, the carried conversation.  This
module gives each node a *prefix index*: a content-addressed map from
chained page digests to physical ``ArenaPlane`` rows.  On a hit the
engine aliases the existing rows (no allocation, no prefill compute for
those tokens) and copy-on-writes the first divergent page, so eviction
and sleep accounting stay exact.

Digests are chained: the digest of page ``i`` commits to the digests of
all pages before it, so a single digest identifies the whole prefix up
to and including that page.  Hashing is keyed by model name — two
models never share an entry even when their planes coincide.

The index pins rows via plane refcounts so prefixes survive the release
of the sequence that created them (vLLM-style).  Pinned bytes are
charged to the node accountant under the ``"prefix-cache"`` context key
and fully recovered by ``flush_model`` / ``flush`` (engine sleep) or
LRU eviction under memory pressure.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

_DIGEST_BYTES = 12


@dataclass(frozen=True)
class PrefixCacheConfig:
    enabled: bool = True
    max_pages: int = 256          # cap on index entries (pinned rows)
    summary_digests: int = 64     # digests advertised in NodeSignal


def root_key(namespace: str) -> str:
    """Chain seed for a namespace (model name)."""
    return f"pfx::{namespace}"


def _chain(parent: str, tokens: Sequence[int]) -> str:
    h = hashlib.blake2b(digest_size=_DIGEST_BYTES)
    h.update(parent.encode())
    h.update(b"|")
    h.update(",".join(str(int(t)) for t in tokens).encode())
    return h.hexdigest()


def page_digests(tokens: Sequence[int], page_tokens: int,
                 namespace: str) -> List[str]:
    """Chained digests for every *full* page of ``tokens``."""
    out: List[str] = []
    parent = root_key(namespace)
    for i in range(len(tokens) // page_tokens):
        parent = _chain(parent, tokens[i * page_tokens:(i + 1) * page_tokens])
        out.append(parent)
    return out


@dataclass
class PrefixEntry:
    digest: str
    model: str
    plane: object                 # ArenaPlane (duck-typed; no import cycle)
    row: int
    tokens: Tuple[int, ...]       # the tokens stored in this page
    parent: str                   # parent digest or root key
    n_prefix_tokens: int          # tokens covered through this page
    lru: int = 0


@dataclass
class PrefixMatch:
    rows: List[int] = field(default_factory=list)   # full-page alias rows
    n_full_tokens: int = 0
    partial_row: Optional[int] = None               # row to alias + COW
    partial_overlap: int = 0                        # leading tokens shared
    digests: List[str] = field(default_factory=list)

    @property
    def tokens_matched(self) -> int:
        return self.n_full_tokens + self.partial_overlap


class PrefixIndex:
    """Per-node refcounted content index over arena rows."""

    def __init__(self, arena, accountant, cfg: PrefixCacheConfig):
        self.arena = arena
        self.acc = accountant
        self.cfg = cfg
        self.entries: Dict[str, PrefixEntry] = {}
        self.children: Dict[str, Set[str]] = {}
        self._clock = 0
        # counters (surface via stats())
        self.lookups = 0
        self.hits = 0
        self.partial_hits = 0
        self.tokens_avoided = 0
        self.inserts = 0
        self.evictions = 0
        self.cow_copies = 0

    # ---------------------------------------------------------------- match
    def match(self, model: str, digests: Sequence[str],
              tokens: Sequence[int], page_tokens: int) -> PrefixMatch:
        """Walk the digest chain; then probe a partial tail page."""
        self.lookups += 1
        m = PrefixMatch()
        parent = root_key(model)
        for d in digests:
            e = self.entries.get(d)
            if e is None or e.parent != parent:
                break
            self._touch(e)
            m.rows.append(e.row)
            m.n_full_tokens = e.n_prefix_tokens
            parent = d
        # partial tail: longest leading-token overlap among children of the
        # last matched digest against the prompt's next page.
        tail = tokens[m.n_full_tokens:m.n_full_tokens + page_tokens]
        best, best_ov = None, 0
        for cd in self.children.get(parent, ()):
            e = self.entries.get(cd)
            if e is None:
                continue
            ov = 0
            for a, b in zip(e.tokens, tail):
                if a != b:
                    break
                ov += 1
            if ov > best_ov:
                best, best_ov = e, ov
        if best is not None and best_ov > 0:
            self._touch(best)
            m.partial_row = best.row
            m.partial_overlap = best_ov
        if m.rows or m.partial_row is not None:
            self.hits += 1
            if m.partial_row is not None:
                self.partial_hits += 1
        return m

    # --------------------------------------------------------------- insert
    def insert(self, model: str, digest: str, parent: str, plane, row: int,
               tokens: Sequence[int], n_prefix_tokens: int) -> bool:
        if digest in self.entries:
            self._touch(self.entries[digest])
            return False
        # polite: make room under both the entry cap and the accountant.
        while self.entries and (len(self.entries) >= self.cfg.max_pages
                                or self.acc.headroom < plane.spec.row_bytes):
            self._evict_lru()
        if len(self.entries) >= self.cfg.max_pages or \
                self.acc.headroom < plane.spec.row_bytes:
            return False
        plane.share_row(row)
        e = PrefixEntry(digest=digest, model=model, plane=plane, row=row,
                        tokens=tuple(int(t) for t in tokens), parent=parent,
                        n_prefix_tokens=n_prefix_tokens)
        self._touch(e)
        self.entries[digest] = e
        self.children.setdefault(parent, set()).add(digest)
        self.inserts += 1
        self._recharge()
        return True

    # ------------------------------------------------------------- eviction
    def _touch(self, e: PrefixEntry) -> None:
        self._clock += 1
        e.lru = self._clock

    def _remove(self, digest: str) -> None:
        e = self.entries.pop(digest)
        kids = self.children.get(e.parent)
        if kids:
            kids.discard(digest)
            if not kids:
                del self.children[e.parent]
        e.plane.drop_row(e.row)
        self._recharge()

    def _evict_lru(self) -> None:
        # evict leaves first so chains stay walkable from the root
        leaves = [d for d in self.entries if d not in self.children]
        pool = leaves or list(self.entries)
        victim = min(pool, key=lambda d: self.entries[d].lru)
        self._remove(victim)
        self.evictions += 1

    def trim(self, n: int) -> int:
        """Evict up to ``n`` entries (memory-pressure hook)."""
        done = 0
        while self.entries and done < n:
            self._evict_lru()
            done += 1
        return done

    def flush_model(self, model: str) -> None:
        for d in [d for d, e in self.entries.items() if e.model == model]:
            self._remove(d)

    def flush(self) -> None:
        for d in list(self.entries):
            self._remove(d)

    # ------------------------------------------------------------ accounting
    def pinned_bytes(self) -> int:
        return sum(e.plane.spec.row_bytes for e in self.entries.values())

    def _recharge(self) -> None:
        b = self.pinned_bytes()
        if b:
            self.acc.register_context("prefix-cache", b)
        else:
            self.acc.unregister_context("prefix-cache")

    def row_pins(self, plane) -> Dict[int, int]:
        pins: Dict[int, int] = {}
        for e in self.entries.values():
            if e.plane is plane:
                pins[e.row] = pins.get(e.row, 0) + 1
        return pins

    # -------------------------------------------------------------- surface
    def summary(self) -> Tuple[str, ...]:
        """Most-recently-used digests, for the NodeSignal snapshot."""
        order = sorted(self.entries.values(), key=lambda e: -e.lru)
        return tuple(e.digest for e in order[:self.cfg.summary_digests])

    def stats(self) -> Dict[str, float]:
        return {
            "prefix_entries": len(self.entries),
            "prefix_pinned_bytes": float(self.pinned_bytes()),
            "prefix_lookups": self.lookups,
            "prefix_hits": self.hits,
            "prefix_partial_hits": self.partial_hits,
            "prefix_tokens_avoided": self.tokens_avoided,
            "prefix_inserts": self.inserts,
            "prefix_evictions": self.evictions,
            "prefix_cow_copies": self.cow_copies,
            "prefix_pages_aliased": getattr(self.arena, "pages_aliased", 0),
        }
