"""Continuous-batching inference engine (the vLLM-role substrate).

Iteration-level scheduling: each ``step()`` admits waiting requests into free
slots (admission is prediction-guided through the Maestro accountant + rho
margin — Eq. 3's R_need gates admission exactly as §III.C describes), then
assembles ONE fused iteration of at most ``max_batch_tokens``: every active
decode sequence contributes its single next-token position, and sequences
still prefilling contribute one fixed-width chunk of ``prefill_chunk_tokens``
prompt tokens each, streamed into the arena page-by-page through
``Model.prefill_chunk``. Prompts therefore never stall decode slots, slots
join and leave at iteration granularity, and the fixed chunk shape means one
traced executable serves every prompt length (no per-length recompiles).
With ``prefill_chunk_tokens=0`` (the default) admission falls back to the
original monolithic one-shot prefill, bit-identical to earlier revisions.
Preemption is boundary-only: requests are only evicted between engine steps,
with their KV accounted and reclaimable.

KV layout: self-attention K/V lives in the node's PHYSICAL paged arena
(:mod:`repro.serving.kv_arena`) — every pool page grant maps to one arena
row, colocated engines on a node share one store, and decode attends through
per-sequence block tables via the Pallas ``paged_attention`` kernel (the
``kernels.ref`` jnp oracle is the CPU fallback, selected once at engine
construction). What stays per-engine is the small dense *state* cache (SSM
state/conv + static cross-attn K/V), which is registered with the accountant
and dropped on sleep/offload. Models with no self-attention KV (pure SSM)
run the dense decode path; their pool grants remain accounting-only.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool
from repro.core.sched.margins import RhoEstimator
from repro.kernels import chunk_prefill as _cp
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref
from repro.models.transformer import Model
from repro.serving.kv_arena import KVArena


def _model_jit(model: "Model", key: tuple, builder):
    """Per-``Model`` cache of the engine's jitted callables.

    Engines are ephemeral — activation churn (sleep/wake under Alg. 2),
    per-policy fleet rebuilds and multi-node zoos construct them by the
    dozen against the same handful of shared ``Model`` objects. A fresh
    ``jax.jit`` wrapper per engine forfeits the XLA compile cache, so a
    10-model fleet recompiled identical programs on every activation;
    keying the wrapper on the model (plus everything the traced program
    closes over: kernel backend, page size) makes compilation once-per-
    program for the model's whole lifetime."""
    cache = getattr(model, "_engine_jit_cache", None)
    if cache is None:
        cache = model._engine_jit_cache = {}
    fn = cache.get(key)
    if fn is None:
        fn = cache[key] = builder()
    return fn


class PromptTooLongError(ValueError):
    """Prompt cannot fit the engine's sequence window (needs <= s_max - 1
    tokens so at least one decode position remains). Raised at ``submit``
    time — silent KV overflow is never possible."""


class EngineStalledError(RuntimeError):
    """``drain()`` exhausted its step budget with work still queued or
    active — the engine made no terminal progress (e.g. a waiting request
    whose reservation can never be granted). Raised instead of silently
    returning a partial result set."""


@dataclasses.dataclass
class Request:
    req_id: int
    tokens: List[int]
    max_new: int = 64
    pred_len: Optional[float] = None      # L_hat from the dispatch gateway
    extras: Optional[Dict[str, Any]] = None
    out: List[int] = dataclasses.field(default_factory=list)
    eos: Optional[int] = None
    truncated: bool = False               # finished early (KV exhausted)
    prefill_avoided: int = 0              # prompt tokens served from cache
    submit_s: float = 0.0                 # wall stamp at engine submit
    ttft_s: float = 0.0                   # wall submit -> first kept token


class Engine:
    def __init__(self, model: Model, params, accountant: MemoryAccountant,
                 max_slots: int = 4, s_max: int = 256,
                 page_tokens: int = 16, arena: Optional[KVArena] = None,
                 kv_backend: Optional[str] = None, prefix_cache=None,
                 prefix_ns: Optional[str] = None,
                 max_batch_tokens: Optional[int] = None,
                 prefill_chunk_tokens: int = 0,
                 decode_horizon: int = 1):
        """``arena``: the node-shared physical page store (a private one is
        created for standalone engines). ``kv_backend``: "pallas" | "ref" |
        "dense" — default picks the Pallas paged kernel on TPU and the jnp
        reference elsewhere; models without self-attention KV always run
        "dense" (state-only). ``prefix_cache``: None/False (off, the
        default — disabled runs stay bit-identical), True, or a
        :class:`~repro.serving.prefix_cache.PrefixCacheConfig`; only takes
        effect on paged engines whose model supports prefix reuse.
        ``prefix_ns``: digest namespace for the prefix index — the fleet
        passes the SERVING model name here so gateway-side request digests
        (computed from the same name) match the node's advertised index;
        defaults to the model config name for standalone engines.
        ``prefill_chunk_tokens``: > 0 switches prefill to fixed-width
        chunks fused into the decode iteration (paged engines whose model
        supports chunked prefill only; others keep monolithic prefill).
        ``max_batch_tokens``: per-iteration token budget across decode
        positions + prefill chunks (None = unbounded; at least one chunk
        always advances so prefill cannot starve).
        ``decode_horizon``: > 1 fuses up to that many decode iterations into
        one jitted on-device program per ``step()`` (paged engines whose
        model supports it only; see :meth:`Model.decode_horizon`) — one host
        sync per horizon instead of per token. 1 (the default) keeps the
        original one-token step, bit-identical to earlier revisions; mixed
        prefill+decode iterations always fall back to one-token decode so
        chunked-prefill fusion semantics are untouched."""
        self.model = model
        self.params = params
        self.acc = accountant
        self.s_max = s_max
        self.max_slots = max_slots
        self.arena = arena if arena is not None else KVArena(page_tokens)
        self.page_tokens = self.arena.page_tokens
        alpha = max(model.cfg.kv_bytes_per_token(
            dtype_bytes=jnp.dtype(model.cfg.dtype).itemsize), 1)
        self.alpha = alpha
        self.pool = VirtualKVPool(accountant,
                                  page_bytes=alpha * self.page_tokens,
                                  page_tokens=self.page_tokens)
        self.pool.set_virtual_budget(model.cfg.name,
                                     alpha * s_max * max_slots * 4)
        bases, n_layers, Hkv, hd, kv_dtype = model.paged_kv_layout()
        if kv_backend is None:
            kv_backend = "pallas" if jax.default_backend() == "tpu" else "ref"
        if n_layers == 0:
            kv_backend = "dense"          # nothing to page: state-only model
        assert kv_backend in ("pallas", "ref", "dense"), kv_backend
        self.kv_backend = kv_backend
        self.paged = kv_backend != "dense"
        self._kv_bases = bases
        self._kv_slots = sorted(bases, key=bases.get)
        self.binding = self.arena.register(
            model.cfg.name, self.pool, s_max=s_max,
            n_layers=n_layers if self.paged else 0,
            n_kv_heads=Hkv, head_dim=hd, dtype=kv_dtype)
        self._pc = None
        self._pc_ns = prefix_ns or model.cfg.name
        if prefix_cache:
            from repro.serving.prefix_cache import PrefixCacheConfig
            pc_cfg = (prefix_cache if isinstance(prefix_cache,
                                                 PrefixCacheConfig)
                      else PrefixCacheConfig())
            if pc_cfg.enabled and self.paged and model.supports_prefix_reuse:
                self._pc = self.arena.enable_prefix_cache(accountant, pc_cfg)
        self._hits: Dict[int, Any] = {}
        self.rho = RhoEstimator()
        self.waiting: Deque[Request] = collections.deque()
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_slots))
        self.positions = np.zeros(max_slots, np.int32)
        self._needs: Dict[int, float] = {}   # admitted R_need, by req_id
        self._state_key = f"{model.cfg.name}::decode-state"
        self._state_bytes = 0
        self.cache = None
        self._ensure_cache()
        self.horizon = 1
        if self.paged:
            attend = (functools.partial(_pa.paged_attention,
                                        page_size=self.page_tokens)
                      if kv_backend == "pallas"
                      else _ref.paged_attention_ref)
            self._decode = _model_jit(
                model, ("decode_paged", kv_backend, self.page_tokens),
                lambda: jax.jit(
                    functools.partial(model.decode_step_paged,
                                      attend=attend),
                    donate_argnums=(1, 2, 3)))
            if decode_horizon and int(decode_horizon) > 1 \
                    and model.supports_decode_horizon:
                self.horizon = int(decode_horizon)
                self._horizon_fwd = _model_jit(
                    model, ("decode_horizon", kv_backend, self.page_tokens,
                            self.horizon),
                    lambda: jax.jit(
                        functools.partial(model.decode_horizon,
                                          attend=attend,
                                          horizon=self.horizon,
                                          page_tokens=self.page_tokens),
                        donate_argnums=(1, 2, 3)))
        else:
            self._decode = _model_jit(
                model, ("decode_dense",),
                lambda: jax.jit(model.decode_step, donate_argnums=(1,)))
        # persistent device-side decode tables (horizon > 1 only): block
        # tables / positions are uploaded when admission, release, eviction
        # or page growth dirties them — never rebuilt per token
        self._dev_bt = None
        self._dev_pos = None
        self._tables_dirty = True

        def _prefill_tok(p, toks, extras):
            # first-token argmax folded into the jitted prefill: the host
            # fetches one int32 per sequence, never a logits row
            logits, cache = model.prefill(p, toks, extras)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._prefill_fwd = _model_jit(model, ("prefill_tok",),
                                       lambda: jax.jit(_prefill_tok))
        self.max_batch_tokens = max_batch_tokens
        self.chunk_tokens = (int(prefill_chunk_tokens)
                             if (prefill_chunk_tokens and self.paged
                                 and model.supports_chunked_prefill) else 0)
        if self.chunk_tokens:
            attend_c = (functools.partial(_cp.chunk_prefill_attention,
                                          page_size=self.page_tokens)
                        if kv_backend == "pallas"
                        else _ref.chunk_prefill_attention_ref)

            def _chunk_tok(p, kp, vp, toks, pos, bt, rows, offs, last_idx):
                logits, kp, vp = model.prefill_chunk(
                    p, kp, vp, toks, pos, bt, rows, offs, last_idx,
                    attend=attend_c)
                return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                        kp, vp)

            self._chunk_fwd = _model_jit(
                model, ("chunk_tok", kv_backend, self.page_tokens),
                lambda: jax.jit(_chunk_tok, donate_argnums=(1, 2)))
        self._prefill_pos: Dict[int, int] = {}   # rid -> prompt tokens done
        # stubbed modality frontends (§IV prototype): encoder-decoder and
        # cross-attention models prefill against precomputed frame / patch
        # embeddings. A request that arrives without them (the text-only
        # gateway plane) gets this engine-constant deterministic stub, so
        # every family of the zoo — whisper and vision included — can be
        # activated and served without shipping modality tensors over the
        # worker transport.
        self._modal_extras = self._make_modal_extras()
        # iteration telemetry: distinct prefill forward shapes (the honest
        # compile-count proxy — jit retraces exactly per new signature),
        # prefill/decode token split, and fused-iteration counts
        self._prefill_shapes: set = set()
        self.prefill_compiles = 0
        self.stat_prefill_tokens = 0
        self.stat_decode_tokens = 0
        self.stat_steps = 0
        self.stat_fused_steps = 0
        # decode-horizon telemetry: horizon launches and decode-side host
        # syncs (one blocking device->host fetch per one-token decode batch
        # OR per horizon launch) — host_syncs_per_token = syncs / tokens
        self.stat_horizon_steps = 0
        self.stat_decode_syncs = 0
        self.finished: List[Request] = []

    # -------------------------------------------------------------- state
    def _ensure_cache(self) -> None:
        """(Re)allocate the dense per-slot cache — SSM state / conv + static
        cross K/V on the paged path, the full dense KV cache on the dense
        fallback — and register its bytes with the accountant so engine
        state is never silently device-resident."""
        if self.cache is not None:
            return
        specs_fn = (self.model.state_cache_specs if self.paged
                    else self.model.cache_specs)
        structs, _ = specs_fn(self.max_slots, self.s_max)
        self.cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  structs)
        nbytes = sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                     for s in jax.tree.leaves(structs))
        self._state_bytes = nbytes
        if nbytes:
            self.acc.register_context(self._state_key, nbytes)

    def release_kv(self) -> None:
        """Drop every byte of device KV this engine holds: boundary-evict
        active requests back to the front of the waiting queue (their arena
        pages return to pool + plane), then free the dense state cache and
        its accountant registration. Called on sleep/offload — a slept model
        must actually return its memory."""
        evicted = [req for rid in list(self.active)
                   if (req := self.evict(rid)) is not None]
        # requeue ahead of the waiting queue, original order kept
        self.waiting.extendleft(reversed(evicted))
        self.binding.release_all()
        if self._pc is not None:       # slept models give back their pins
            self._pc.flush_model(self._pc_ns)
        if self.cache is not None:
            self.cache = None
            if self._state_bytes:
                self.acc.unregister_context(self._state_key)
            self._state_bytes = 0
        self._dev_bt = self._dev_pos = None     # device tables go with KV
        self._tables_dirty = True

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        if len(req.tokens) > self.s_max - 1:
            raise PromptTooLongError(
                f"prompt of {len(req.tokens)} tokens exceeds the engine "
                f"window (s_max={self.s_max}, >=1 decode slot required)")
        if not req.submit_s:
            req.submit_s = time.perf_counter()
        self.waiting.append(req)

    def _r_need(self, req: Request) -> float:
        pred = req.pred_len if req.pred_len is not None else req.max_new
        return self.rho.r_need(self.alpha * (len(req.tokens) + pred))

    def _admit(self) -> List[Request]:
        admitted = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            need = self._r_need(req)
            # pages must cover prompt + the first decode write, but never
            # exceed the sequence window (KV past s_max is unusable, and
            # block tables are sized for exactly ceil(s_max/page) pages)
            need_tokens = min(max(int(need / self.alpha),
                                  len(req.tokens) + 1), self.s_max)
            hit = alias = None
            if self._pc is not None:
                hit = self._prefix_lookup(req)
                alias = list(hit.rows)
                if hit.partial_row is not None:
                    alias.append(hit.partial_row)
                alias = alias or None
            if not self.binding.alloc_seq(req.req_id, self.model.cfg.name,
                                          need_tokens, alias_rows=alias):
                break   # memory-infeasible: reject-for-now (backpressure)
            if hit is not None:
                if hit.partial_row is not None:
                    # the divergent tail lands mid-page: privatise that page
                    # (copy-on-write) before suffix prefill overwrites it —
                    # the index pin guarantees the row is shared, so this
                    # always copies
                    if self.binding.make_private(req.req_id, len(hit.rows)):
                        self._pc.cow_copies += 1
                self._hits[req.req_id] = hit
            self.waiting.popleft()
            slot = self.free_slots.pop()
            self.slot_of[req.req_id] = slot
            self.active[req.req_id] = req
            self._needs[req.req_id] = need
            self._tables_dirty = True
            admitted.append(req)
        return admitted

    def _prefix_lookup(self, req: Request):
        """Match the prompt against the node prefix index, capped so the
        final prompt token always runs through prefill (its logit seeds
        decoding)."""
        from repro.serving.prefix_cache import page_digests
        name = self._pc_ns
        digs = page_digests(req.tokens, self.page_tokens, name)
        m = self._pc.match(name, digs, req.tokens, self.page_tokens)
        P = len(req.tokens)
        if m.n_full_tokens >= P:          # whole prompt cached: keep 1 page
            m.rows.pop()
            m.n_full_tokens -= self.page_tokens
            m.partial_row, m.partial_overlap = None, 0
        if m.partial_row is not None:
            m.partial_overlap = min(m.partial_overlap,
                                    P - 1 - m.n_full_tokens)
            if m.partial_overlap <= 0:
                m.partial_row, m.partial_overlap = None, 0
        m.digests = digs
        return m

    # -------------------------------------------------------------- prefill
    def _note_prefill_shape(self, sig) -> None:
        """Count distinct prefill forward signatures — the compile-count
        telemetry. jit retraces exactly once per new signature, so this is
        the honest recompile proxy without reaching into jit internals."""
        if sig not in self._prefill_shapes:
            self._prefill_shapes.add(sig)
            self.prefill_compiles += 1

    def _first_token(self, req: Request, tok: int) -> None:
        req.out.append(tok)
        if not req.ttft_s and req.submit_s:
            req.ttft_s = time.perf_counter() - req.submit_s

    def _prefill(self, req: Request) -> None:
        self._ensure_cache()
        slot = self.slot_of[req.req_id]
        hit = self._hits.pop(req.req_id, None)
        if hit is not None and hit.tokens_matched > 0:
            self._prefill_suffix(req, hit, slot)
        else:
            self._prefill_full(req, slot)
        if self._pc is not None:
            digs = (hit.digests if hit is not None else None)
            self._index_prompt(req, digs)

    def _begin_chunked(self, req: Request) -> None:
        """Register a newly admitted request with the chunked-prefill plan:
        its prompt streams into the arena ``chunk_tokens`` at a time across
        the next iterations (cache-hit prefixes are skipped — the matched
        pages are already aliased into this sequence's block table, so the
        first chunk starts right after them)."""
        hit = self._hits.get(req.req_id)
        p0 = hit.tokens_matched if hit is not None else 0
        self._prefill_pos[req.req_id] = p0
        if p0:
            req.prefill_avoided = p0
            self._pc.tokens_avoided += p0

    def _make_modal_extras(self) -> Optional[Dict[str, Any]]:
        """Deterministic stub inputs for the model's modality frontend
        (None for text-only models): whisper-style frames [1,F,D] or VLM
        patch embeddings [1,N,C], seeded once per engine so repeated runs
        are bit-identical."""
        cfg = self.model.cfg
        key = jax.random.PRNGKey(0)
        if cfg.encoder is not None:
            return {"frames": jax.random.normal(
                key, (1, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)}
        if cfg.cross_attn is not None and cfg.family == "vlm":
            cd = cfg.cross_attn.ctx_dim or cfg.d_model
            return {"ctx_embeds": jax.random.normal(
                key, (1, cfg.cross_attn.n_ctx_tokens, cd), cfg.dtype)}
        return None

    def _prefill_full(self, req: Request, slot: int) -> None:
        toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
        first_tok, cache = self._prefill_fwd(self.params, toks,
                                             req.extras
                                             or self._modal_extras or {})
        P = len(req.tokens)
        self._note_prefill_shape(("full", P))
        self.stat_prefill_tokens += P
        if self.paged:
            # [G,1,P,Hkv,hd] per slot -> layer-stacked [L,P,Hkv,hd] in
            # plane layout order (slot base + group)
            k_all = jnp.concatenate(
                [cache[s]["k"][:, 0] for s in self._kv_slots], axis=0)
            v_all = jnp.concatenate(
                [cache[s]["v"][:, 0] for s in self._kv_slots], axis=0)
            self.binding.write_prompt(req.req_id, k_all, v_all)

        def write(dst, src):
            # dst [G, max_slots, S_max, ...]; src [G, 1, P, ...]
            if dst.shape[2] == src.shape[2]:      # static cross entries
                return dst.at[:, slot].set(src[:, 0])
            return dst.at[:, slot, :P].set(src[:, 0])

        def write_state(dst, src):                 # ssm state/conv
            return dst.at[:, slot].set(src[:, 0])

        for name, entry in cache.items():
            if self.paged and name in self._kv_bases:
                continue                           # lives in the arena
            for kname, arr in entry.items():
                tgt = self.cache[name][kname]
                if kname in ("k", "v"):
                    self.cache[name][kname] = write(tgt, arr)
                else:
                    self.cache[name][kname] = write_state(tgt, arr)
        self.positions[slot] = P
        self._tables_dirty = True
        self._first_token(req, int(first_tok[0]))

    def _prefill_suffix(self, req: Request, hit, slot: int) -> None:
        """Cache-hit prefill: gather matched prefix KV from the arena rows
        this sequence aliases, run the forward only over the unmatched
        suffix, and scatter the suffix KV behind the prefix."""
        M = hit.tokens_matched
        plane = self.binding.plane
        L = self.binding.n_layers
        page = self.page_tokens
        n_pages = -(-M // page)
        idx = jnp.asarray(self.binding.seq_rows(req.req_id)[:n_pages],
                          jnp.int32)
        tail = plane.k.shape[3:]
        pk = plane.k[:L, idx].reshape((L, n_pages * page) + tail)[:, :M]
        pv = plane.v[:L, idx].reshape((L, n_pages * page) + tail)[:, :M]
        toks = jnp.asarray(req.tokens[M:], jnp.int32)[None, :]
        self._note_prefill_shape(("suffix", len(req.tokens) - M, M))
        self.stat_prefill_tokens += len(req.tokens) - M
        model = self.model

        def _suffix_tok(p, toks, pk, pv):
            logits, k_sfx, v_sfx = model.prefill_suffix(p, toks, pk, pv)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    k_sfx, v_sfx)

        first_tok, k_sfx, v_sfx = _model_jit(
            self.model, ("prefill_suffix_tok",),
            lambda: jax.jit(_suffix_tok))(
            self.params, toks, pk, pv)
        self.binding.write_prompt_at(req.req_id, k_sfx[:, 0], v_sfx[:, 0], M)
        self.positions[slot] = len(req.tokens)
        self._tables_dirty = True
        self._first_token(req, int(first_tok[0]))
        req.prefill_avoided = M
        self._pc.tokens_avoided += M

    def _index_prompt(self, req: Request, digs=None) -> None:
        """Publish every full prompt page into the prefix index (pinning its
        row) so successor stages sharing this prefix can alias it."""
        from repro.serving.prefix_cache import page_digests, root_key
        name = self._pc_ns
        page = self.page_tokens
        if digs is None:
            digs = page_digests(req.tokens, page, name)
        rows = self.binding.seq_rows(req.req_id)
        parent = root_key(name)
        for i, d in enumerate(digs):
            self._pc.insert(name, d, parent, self.binding.plane, rows[i],
                            req.tokens[i * page:(i + 1) * page],
                            n_prefix_tokens=(i + 1) * page)
            parent = d

    def _prefill_chunk_batch(self, rids: List[int]) -> None:
        """One fused chunk forward for the given mid-prefill sequences: each
        contributes the next ``chunk_tokens`` of its prompt at fixed shape
        [max_slots, C]. Slots not advancing this iteration (idle, decoding,
        or budget-deferred) are padding — their tokens/positions are zero and
        their write coordinates point at the plane's null row, so the forward
        is shape-stable and their garbage rows are discarded. A sequence
        whose chunk reaches the end of its prompt gets its first output
        token from that chunk's last-row logits and joins decode at the NEXT
        iteration (join-at-iteration-granularity)."""
        self._ensure_cache()
        C = self.chunk_tokens
        page = self.page_tokens
        toks = np.zeros((self.max_slots, C), np.int32)
        pos = np.zeros((self.max_slots, C), np.int32)
        rows = np.zeros((self.max_slots, C), np.int32)
        offs = np.zeros((self.max_slots, C), np.int32)
        bt = np.zeros((self.max_slots, self.binding.bt_width), np.int32)
        last_idx = np.zeros(self.max_slots, np.int32)
        for rid in rids:
            req = self.active[rid]
            slot = self.slot_of[rid]
            p0 = self._prefill_pos[rid]
            n = min(C, len(req.tokens) - p0)
            table = self.binding.row_table(rid)
            bt[slot] = table
            abs_t = np.arange(p0, p0 + n)
            toks[slot, :n] = req.tokens[p0:p0 + n]
            pos[slot, :n] = abs_t
            rows[slot, :n] = table[abs_t // page]
            offs[slot, :n] = abs_t % page
            last_idx[slot] = n - 1
            self._prefill_pos[rid] = p0 + n
            self.stat_prefill_tokens += n
        self._note_prefill_shape(("chunk", C))
        self._tables_dirty = True
        plane = self.binding.plane
        tok_dev, plane.k, plane.v = self._chunk_fwd(
            self.params, plane.k, plane.v, jnp.asarray(toks),
            jnp.asarray(pos), jnp.asarray(bt), jnp.asarray(rows),
            jnp.asarray(offs), jnp.asarray(last_idx))
        nxt = np.asarray(tok_dev)
        for rid in rids:
            req = self.active[rid]
            if self._prefill_pos[rid] < len(req.tokens):
                continue                       # more chunks to stream
            del self._prefill_pos[rid]
            slot = self.slot_of[rid]
            self.positions[slot] = len(req.tokens)
            self._first_token(req, int(nxt[slot]))
            if self._pc is not None:
                hit = self._hits.pop(rid, None)
                self._index_prompt(req, hit.digests if hit is not None
                                   else None)

    # --------------------------------------------------------------- decode
    def step(self) -> List[Request]:
        """One fused engine iteration; returns the requests that finished
        DURING THIS CALL only (the accumulated history stays on
        ``self.finished`` for owners that drain it wholesale)."""
        n0 = len(self.finished)
        self.stat_steps += 1
        for req in self._admit():
            if self.chunk_tokens:
                self._begin_chunked(req)
            else:
                self._prefill(req)
        # sequences still streaming their prompt join decode at the NEXT
        # iteration after their final chunk — snapshot the decode set first
        decode_rids = [rid for rid in self.active
                       if rid not in self._prefill_pos]
        # mixed prefill+decode iterations fall back to one-token decode so
        # chunked-prefill fusion semantics stay untouched; pure-decode
        # iterations launch the on-device horizon
        use_horizon = self.horizon > 1 and not self._prefill_pos
        caps: Dict[int, int] = {}
        if decode_rids and self.paged:
            # grow page coverage for this step's token writes; a sequence
            # the pool cannot extend finishes truncated (honest
            # backpressure instead of silent overflow)
            for rid in list(decode_rids):
                pos = int(self.positions[self.slot_of[rid]])
                if use_horizon:
                    # pre-grant up to a horizon's worth of pages; a partial
                    # grant caps that lane's emission budget (it stays
                    # active and retries next step), a zero grant truncates
                    # exactly like the one-token path
                    req = self.active[rid]
                    want = min(self.horizon, req.max_new - len(req.out),
                               self.s_max - 1 - pos)
                    got = self._pregrant(rid, pos, want)
                    if got > 0:
                        caps[rid] = got
                        continue
                elif self.binding.ensure_tokens(rid, pos + 1):
                    continue
                self.active[rid].truncated = True
                self._release(rid)
                decode_rids.remove(rid)
        if self._prefill_pos:
            # token-budget split: decode contributes one position per
            # sequence, the remainder admits whole prefill chunks; at least
            # one chunk always advances (prefill cannot starve)
            if self.max_batch_tokens is None:
                n_adv = len(self._prefill_pos)
            else:
                room = self.max_batch_tokens - len(decode_rids)
                n_adv = max(room // self.chunk_tokens, 1)
            advance = list(self._prefill_pos)[:n_adv]
            self._prefill_chunk_batch(advance)
            if decode_rids:
                self.stat_fused_steps += 1
        if decode_rids and use_horizon and self.paged:
            self._decode_horizon_batch(decode_rids, caps)
        elif decode_rids:
            self._ensure_cache()
            toks = np.zeros((self.max_slots, 1), np.int32)
            for rid in decode_rids:
                toks[self.slot_of[rid], 0] = self.active[rid].out[-1]
            if self.paged:
                logits = self._decode_paged(toks, decode_rids)
            else:
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(self.positions))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            self.stat_decode_syncs += 1
            self.stat_decode_tokens += len(decode_rids)
            self._tables_dirty = True
            done = []
            for rid in decode_rids:
                req = self.active[rid]
                slot = self.slot_of[rid]
                tok = int(nxt[slot])
                req.out.append(tok)
                self.positions[slot] += 1
                if (len(req.out) >= req.max_new
                        or (req.eos is not None and tok == req.eos)
                        or self.positions[slot] >= self.s_max - 1):
                    done.append(rid)
            for rid in done:
                self._release(rid)
        return self.finished[n0:]

    def _pregrant(self, rid: int, pos: int, want: int) -> int:
        """Pre-grant pages for up to ``want`` horizon writes starting at
        ``pos``. Returns the emission budget actually covered (0 = not even
        one write grantable -> caller truncates, the same backpressure as
        the one-token path). Grants are page-granular: when the pool
        refuses the full horizon the budget falls back page by page, down
        to whatever the current grant already covers."""
        page = self.page_tokens
        have = self.binding.token_capacity(rid) - pos
        e = want
        while e > max(have, 0):
            if self.binding.ensure_tokens(rid, pos + e):
                self._tables_dirty = True   # new pages -> new block rows
                break
            # largest budget needing one page fewer
            e = (pos + e - 1) // page * page - pos
        if e <= 0:
            return 0
        # covered by pages already granted: record the token high-water
        # mark with the pool (never allocates here, cannot fail)
        self.binding.ensure_tokens(rid, pos + e)
        if self._pc is not None:
            # a horizon write must never land on a shared row: privatise
            # every page the launch will touch before it starts
            for pidx in range(pos // page, (pos + e - 1) // page + 1):
                if self.binding.make_private(rid, pidx):
                    self._pc.cow_copies += 1
                    self._tables_dirty = True
        return e

    def _decode_horizon_batch(self, decode_rids: List[int],
                              caps: Dict[int, int]) -> None:
        """One on-device horizon launch: up to ``self.horizon`` decode
        iterations for every decoding lane, ONE host sync for the token
        block. Per-lane stop masks freeze finished lanes on device; the
        host re-applies the same done predicate over the emitted tokens to
        release finished requests (boundary preemption granularity becomes
        the horizon launch, measured — not asserted — in
        ``benchmarks/decode_horizon.py``)."""
        self._ensure_cache()
        B = self.max_slots
        live = np.zeros(B, bool)
        last = np.zeros(B, np.int32)
        rem = np.ones(B, np.int32)
        cap = np.zeros(B, np.int32)
        eos = np.full(B, -1, np.int32)
        for rid in decode_rids:
            slot = self.slot_of[rid]
            req = self.active[rid]
            live[slot] = True
            last[slot] = req.out[-1]
            rem[slot] = req.max_new - len(req.out)
            cap[slot] = caps[rid]
            if req.eos is not None:
                eos[slot] = req.eos
        if self._tables_dirty or self._dev_bt is None:
            bt = np.zeros((B, self.binding.bt_width), np.int32)
            for rid in decode_rids:
                bt[self.slot_of[rid]] = self.binding.row_table(rid)
            self._dev_bt = jnp.asarray(bt)
            self._dev_pos = jnp.asarray(self.positions)
            self._tables_dirty = False
        plane = self.binding.plane
        tok_blk, self._dev_pos, self.cache, plane.k, plane.v = \
            self._horizon_fwd(
                self.params, self.cache, plane.k, plane.v, self._dev_bt,
                self._dev_pos, jnp.asarray(last), jnp.asarray(live),
                jnp.asarray(rem), jnp.asarray(cap), jnp.asarray(eos),
                jnp.int32(self.s_max))
        blk = np.asarray(tok_blk)               # the ONE host sync
        self.stat_decode_syncs += 1
        self.stat_horizon_steps += 1
        done = []
        for rid in decode_rids:
            req = self.active[rid]
            slot = self.slot_of[rid]
            for t in blk[slot]:
                if t < 0:
                    break                       # lane froze on device
                tok = int(t)
                req.out.append(tok)
                self.positions[slot] += 1
                self.stat_decode_tokens += 1
                if (len(req.out) >= req.max_new
                        or (req.eos is not None and tok == req.eos)
                        or self.positions[slot] >= self.s_max - 1):
                    done.append(rid)
                    break
        for rid in done:
            self._release(rid)

    def _decode_paged(self, toks: np.ndarray, decode_rids: List[int]):
        """One paged decode step: build block tables / write coordinates for
        the decoding slots and run the arena-backed decode. Idle and
        mid-prefill slots point at the plane's null row (reads and writes
        land there harmlessly)."""
        bt = np.zeros((self.max_slots, self.binding.bt_width), np.int32)
        seq_lens = np.ones(self.max_slots, np.int32)
        rows = np.zeros(self.max_slots, np.int32)
        offs = np.zeros(self.max_slots, np.int32)
        for rid in decode_rids:
            slot = self.slot_of[rid]
            pos = int(self.positions[slot])
            if self._pc is not None and self.binding.make_private(
                    rid, pos // self.page_tokens):
                # defensive: a decode write must never land on a shared row
                self._pc.cow_copies += 1
            table = self.binding.row_table(rid)
            bt[slot] = table
            seq_lens[slot] = pos + 1
            rows[slot] = table[pos // self.page_tokens]
            offs[slot] = pos % self.page_tokens
        plane = self.binding.plane
        logits, self.cache, plane.k, plane.v = self._decode(
            self.params, self.cache, plane.k, plane.v, jnp.asarray(bt),
            jnp.asarray(seq_lens), jnp.asarray(rows), jnp.asarray(offs),
            jnp.asarray(toks), jnp.asarray(self.positions))
        return logits

    def _release(self, rid: int) -> None:
        req = self.active.pop(rid)
        slot = self.slot_of.pop(rid)
        actual = self.alpha * (len(req.tokens) + len(req.out))
        # calibrate against the reservation ADMISSION charged — recomputing
        # r_need here would read a rho already moved by earlier releases
        self.rho.observe(actual, max(self._needs.pop(rid, 1.0), 1.0))
        self.binding.free_seq(rid)      # pages -> pool -> arena rows
        self.free_slots.append(slot)
        self.positions[slot] = 0
        self._tables_dirty = True
        self.finished.append(req)

    # ------------------------------------------------------------ preemption
    def cancel(self, req_id: int) -> Optional[Request]:
        """Withdraw a request still waiting for admission (no KV held)."""
        for i, r in enumerate(self.waiting):
            if r.req_id == req_id:
                del self.waiting[i]
                return r
        return None

    def evict(self, req_id: int) -> Optional[Request]:
        """Boundary preemption: release an active request between engine
        steps. Its KV pages return to the pool, the arena plane and the
        accountant, the slot frees, and the partial output is discarded —
        the caller requeues the stage, which restarts from its prompt
        (§III.D boundary semantics)."""
        req = self.active.pop(req_id, None)
        if req is None:
            return self.cancel(req_id)
        slot = self.slot_of.pop(req_id)
        self._needs.pop(req_id, None)
        self._hits.pop(req_id, None)
        # mid-chunked-prefill eviction: drop the streaming cursor too — the
        # partially-written pages go back with free_seq below, and a later
        # re-admission restarts the prompt from scratch
        self._prefill_pos.pop(req_id, None)
        self.binding.free_seq(req_id)
        self.free_slots.append(slot)
        self.positions[slot] = 0
        self._tables_dirty = True
        req.out.clear()
        req.ttft_s = 0.0            # the discarded first token doesn't count
        return req

    def drain(self, max_steps: int = 10_000) -> List[Request]:
        steps = max_steps
        while (self.waiting or self.active) and steps:
            self.step()
            steps -= 1
        if self.waiting or self.active:
            raise EngineStalledError(
                f"drain({max_steps}) exhausted with {len(self.waiting)} "
                f"waiting / {len(self.active)} active requests still held")
        out, self.finished = self.finished, []
        return out
