"""Continuous-batching inference engine (the vLLM-role substrate).

Iteration-level scheduling: each ``step()`` admits waiting requests into free
slots (admission is prediction-guided through the Maestro accountant + rho
margin — Eq. 3's R_need gates admission exactly as §III.C describes), runs
prefill for newly admitted sequences, then one batched decode step for all
active sequences. Preemption is boundary-only: requests are only evicted
between engine steps, with their KV accounted and reclaimable.

KV layout: per-slot contiguous cache (the model's decode cache) whose pages
are accounted through the VirtualKVPool; the physical paged arena + Pallas
paged_attention kernel live in repro.kernels (the accounting semantics —
virtual budget >> physical, admission-checked growth — are identical).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.kv_pool import VirtualKVPool
from repro.core.sched.margins import RhoEstimator
from repro.models.transformer import Model


@dataclasses.dataclass
class Request:
    req_id: int
    tokens: List[int]
    max_new: int = 64
    pred_len: Optional[float] = None      # L_hat from the dispatch gateway
    extras: Optional[Dict[str, Any]] = None
    out: List[int] = dataclasses.field(default_factory=list)
    eos: Optional[int] = None


class Engine:
    def __init__(self, model: Model, params, accountant: MemoryAccountant,
                 max_slots: int = 4, s_max: int = 256,
                 page_tokens: int = 16):
        self.model = model
        self.params = params
        self.acc = accountant
        self.s_max = s_max
        self.max_slots = max_slots
        alpha = max(model.cfg.kv_bytes_per_token(), 1)
        self.alpha = alpha
        self.pool = VirtualKVPool(accountant, page_bytes=alpha * page_tokens,
                                  page_tokens=page_tokens)
        self.pool.set_virtual_budget(model.cfg.name,
                                     alpha * s_max * max_slots * 4)
        self.rho = RhoEstimator()
        self.waiting: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_slots))
        self.positions = np.zeros(max_slots, np.int32)
        structs, _ = model.cache_specs(max_slots, s_max)
        self.cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  structs)
        self.finished: List[Request] = []
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _r_need(self, req: Request) -> float:
        pred = req.pred_len if req.pred_len is not None else req.max_new
        return self.rho.r_need(self.alpha * (len(req.tokens) + pred))

    def _admit(self) -> List[Request]:
        admitted = []
        while self.waiting and self.free_slots:
            req = self.waiting[0]
            need = self._r_need(req)
            if not self.pool.alloc_seq(req.req_id, self.model.cfg.name,
                                       int(need / self.alpha)):
                break   # memory-infeasible: reject-for-now (backpressure)
            self.waiting.pop(0)
            slot = self.free_slots.pop()
            self.slot_of[req.req_id] = slot
            self.active[req.req_id] = req
            admitted.append(req)
        return admitted

    # -------------------------------------------------------------- prefill
    def _prefill(self, req: Request) -> None:
        slot = self.slot_of[req.req_id]
        toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
        logits, cache = self.model.prefill(self.params, toks,
                                           req.extras or {})
        P = len(req.tokens)

        def write(dst, src):
            # dst [G, max_slots, S_max, ...]; src [G, 1, P, ...]
            if dst.shape[2] == src.shape[2]:      # static cross entries
                return dst.at[:, slot].set(src[:, 0])
            return dst.at[:, slot, :P].set(src[:, 0])

        def write_state(dst, src):                 # ssm state/conv
            return dst.at[:, slot].set(src[:, 0])

        for name, entry in cache.items():
            for kname, arr in entry.items():
                tgt = self.cache[name][kname]
                if kname in ("k", "v"):
                    self.cache[name][kname] = write(tgt, arr)
                else:
                    self.cache[name][kname] = write_state(tgt, arr)
        self.positions[slot] = P
        req.out.append(int(jnp.argmax(logits[0])))

    # --------------------------------------------------------------- decode
    def step(self) -> List[Request]:
        """One engine iteration; returns requests finished this step."""
        for req in self._admit():
            self._prefill(req)
        if self.active:
            toks = np.zeros((self.max_slots, 1), np.int32)
            for rid, req in self.active.items():
                toks[self.slot_of[rid], 0] = req.out[-1]
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.positions))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            done = []
            for rid, req in list(self.active.items()):
                slot = self.slot_of[rid]
                tok = int(nxt[slot])
                req.out.append(tok)
                self.positions[slot] += 1
                if (len(req.out) >= req.max_new
                        or (req.eos is not None and tok == req.eos)
                        or self.positions[slot] >= self.s_max - 1):
                    done.append(rid)
            for rid in done:
                self._release(rid)
        return [r for r in self.finished]

    def _release(self, rid: int) -> None:
        req = self.active.pop(rid)
        slot = self.slot_of.pop(rid)
        actual = self.alpha * (len(req.tokens) + len(req.out))
        self.rho.observe(actual, max(self._r_need(req), 1.0))
        self.pool.free_seq(rid)
        self.pool.reclaim_unmapped()    # elastic shrink back to the pool
        self.free_slots.append(slot)
        self.positions[slot] = 0
        self.finished.append(req)

    # ------------------------------------------------------------ preemption
    def cancel(self, req_id: int) -> Optional[Request]:
        """Withdraw a request still waiting for admission (no KV held)."""
        for i, r in enumerate(self.waiting):
            if r.req_id == req_id:
                return self.waiting.pop(i)
        return None

    def evict(self, req_id: int) -> Optional[Request]:
        """Boundary preemption: release an active request between engine
        steps. Its KV pages return to the pool (and the accountant), the slot
        frees, and the partial output is discarded — the caller requeues the
        stage, which restarts from its prompt (§III.D boundary semantics)."""
        req = self.active.pop(req_id, None)
        if req is None:
            return self.cancel(req_id)
        slot = self.slot_of.pop(req_id)
        self.pool.free_seq(req_id)
        self.pool.reclaim_unmapped()
        self.free_slots.append(slot)
        self.positions[slot] = 0
        req.out.clear()
        return req

    def drain(self, max_steps: int = 10_000) -> List[Request]:
        while (self.waiting or self.active) and max_steps:
            self.step()
            max_steps -= 1
        out, self.finished = self.finished, []
        return out
