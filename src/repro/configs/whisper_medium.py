"""whisper-medium — audio enc-dec, 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.

Conv frontend is a STUB: input_specs() provides precomputed frame embeddings
(batch, 1500, d_model). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig, EncoderConfig, register

WHISPER_MEDIUM = register(ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,          # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    rope_theta=1e4,       # whisper uses learned positions; rope stands in (noted)
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    source="arXiv:2212.04356; unverified",
))
