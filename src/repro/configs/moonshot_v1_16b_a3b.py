"""moonshot-v1-16b-a3b — moe, 48L d_model=2048 16H (kv=16) d_ff=1408 vocab=163840.

MoE 64 experts top-6 (kimi/moonlight style). [hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, register

MOONSHOT_V1_16B_A3B = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    rope_theta=5e6,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, every=1),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
))
