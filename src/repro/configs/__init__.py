from repro.configs.base import (
    SHAPES,
    ArchConfig,
    CrossAttnConfig,
    EncoderConfig,
    MoEConfig,
    SSMConfig,
    all_cells,
    get_config,
    input_specs,
    list_configs,
    register,
)

__all__ = [
    "SHAPES", "ArchConfig", "CrossAttnConfig", "EncoderConfig", "MoEConfig",
    "SSMConfig", "all_cells", "get_config", "input_specs", "list_configs",
    "register",
]
