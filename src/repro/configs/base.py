"""Architecture configuration system.

Every servable/trainable model in the zoo is described by an ``ArchConfig``.
Configs are pure data (dataclasses) — model code in ``repro.models`` consumes
them; ``input_specs()`` produces ShapeDtypeStruct stand-ins for the dry-run
(never allocates device memory).

Families:
  dense   — decoder-only transformer (GQA, optional qk_norm / qkv bias)
  moe     — dense skeleton with MoE FFN layers
  ssm     — attention-free Mamba2 (SSD) stack
  hybrid  — interleaved Mamba2 + attention (+ optional MoE)
  encdec  — encoder-decoder (Whisper-style); frontend stubbed as frame embeddings
  vlm     — decoder-only with interleaved cross-attention layers over patch embeds
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shape suite (assigned): every LM arch is paired with these four shapes.
# ---------------------------------------------------------------------------

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # Apply MoE on layers where (layer_idx % every) == offset.
    every: int = 1
    offset: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    n_groups: int = 1  # B/C shared across heads per group (Mamba2 default)
    conv_dim: int = 4  # depthwise conv width (stubbed as small causal conv)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class CrossAttnConfig:
    """Interleaved cross-attention (VLM) or enc-dec cross-attention."""
    every: int = 5          # cross-attn layer each `every` layers (vlm)
    offset: int = 0
    n_ctx_tokens: int = 1601  # patch / frame embedding count
    ctx_dim: int = 0          # 0 => d_model


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 24
    n_frames: int = 1500   # precomputed frame embeddings (conv frontend stubbed)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    ffn_gelu: bool = False       # 2-matrix GELU MLP instead of SwiGLU
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    cross_attn: Optional[CrossAttnConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid: attention on layers where (idx % attn_every) == attn_offset,
    # Mamba2 elsewhere. attn_every=1 => all attention.
    attn_every: int = 1
    attn_offset: int = 0
    max_seq_len: int = 1 << 20
    dtype: Any = jnp.bfloat16
    # Source tag from the assignment table.
    source: str = ""

    # ----- derived ---------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def is_attn_layer(self, idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return idx % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return idx % self.moe.every == self.moe.offset

    def is_cross_layer(self, idx: int) -> bool:
        if self.cross_attn is None or self.family == "encdec":
            return False
        return idx % self.cross_attn.every == self.cross_attn.offset

    @property
    def layer_pattern_period(self) -> int:
        """Smallest period covering the layer heterogeneity (for scan grouping)."""
        p = 1
        if self.family == "hybrid":
            p = _lcm(p, self.attn_every)
        if self.moe is not None:
            p = _lcm(p, self.moe.every)
        if self.cross_attn is not None and self.family != "encdec":
            p = _lcm(p, self.cross_attn.every)
        return p

    @property
    def n_attn_layers(self) -> int:
        return sum(1 for i in range(self.n_layers) if self.is_attn_layer(i))

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence handling => long_500k applicable."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    # ----- parameter / memory model (analytic; also cross-checked in tests) --
    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        d, hd = self.d_model, self.head_dim_
        total = self.vocab * d  # embed
        if not self.tie_embeddings:
            total += self.vocab * d  # lm head
        for i in range(self.n_layers):
            if self.is_attn_layer(i):
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                o = (self.n_heads * hd) * d
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                total += qkv + o + d  # + attn norm (ffn norm in _ffn_params)
                if self.qk_norm:
                    total += 2 * hd
            elif self.ssm is not None:
                total += _ssm_params(self, d)
            if self.is_cross_layer(i):
                cd = self.cross_attn.ctx_dim or d
                total += d * (self.n_heads * hd) + 2 * cd * (self.n_kv_heads * hd) \
                    + (self.n_heads * hd) * d + d
            total += _ffn_params(self, i, d)
        total += d  # final norm
        if self.encoder is not None:
            enc = 0
            for _ in range(self.encoder.n_layers):
                qkv = self.d_model * (self.n_heads * hd) * 3
                o = (self.n_heads * hd) * self.d_model
                ffn = 2 * self.d_model * self.d_ff
                enc += qkv + o + ffn + 2 * self.d_model
            total += enc
            # decoder cross-attn blocks (one per decoder layer)
            total += self.n_layers * (
                self.d_model * (self.n_heads * hd)
                + 2 * self.d_model * (self.n_kv_heads * hd)
                + (self.n_heads * hd) * self.d_model + self.d_model)
            total += self.d_model  # encoder final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        # subtract non-routed expert weights
        per_expert = 3 * self.d_model * self.moe.d_ff_expert
        n_moe_layers = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        inactive = n_moe_layers * (self.moe.n_experts - self.moe.top_k) * per_expert
        return total - inactive

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """α(M) of Eq. 3 — per-token KV footprint (hybrid: attn layers only;
        ssm: 0, state is O(1))."""
        return (self.n_attn_layers * 2 * self.n_kv_heads * self.head_dim_
                * dtype_bytes)

    def ssm_state_bytes(self, dtype_bytes: int = 4) -> int:
        """Constant per-sequence recurrent state (SSM / hybrid)."""
        if self.ssm is None:
            return 0
        n_ssm = self.n_layers - self.n_attn_layers
        h = self.ssm.n_heads(self.d_model)
        return n_ssm * h * self.ssm.head_dim * self.ssm.d_state * dtype_bytes

    def weight_bytes(self, dtype_bytes: int = 2) -> int:
        return self.param_count() * dtype_bytes

    # ----- shape suite -----------------------------------------------------
    def applicable_shapes(self) -> List[str]:
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context:
            names.append("long_500k")
        return names

    def skipped_shapes(self) -> Dict[str, str]:
        if self.supports_long_context:
            return {}
        return {"long_500k": "pure full-attention arch: O(L^2)/dense-KV at 512k "
                             "is out of contract (see DESIGN.md §4)"}

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family (tiny but structurally faithful)."""
        changes: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 * self.layer_pattern_period),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256,
            vocab=512,
            dtype=jnp.float32,
            max_seq_len=4096,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff_expert=128)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.cross_attn is not None:
            changes["cross_attn"] = dataclasses.replace(
                self.cross_attn, n_ctx_tokens=24, ctx_dim=0)
        if self.encoder is not None:
            changes["encoder"] = dataclasses.replace(
                self.encoder, n_layers=2, n_frames=24)
        return dataclasses.replace(self, **changes)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


def _ffn_params(cfg: ArchConfig, idx: int, d: int) -> int:
    if cfg.is_moe_layer(idx):
        return cfg.moe.n_experts * 3 * d * cfg.moe.d_ff_expert + d * cfg.moe.n_experts + d
    if cfg.family == "ssm" or (cfg.family == "hybrid" and not cfg.is_attn_layer(idx)):
        return 0  # Mamba2 block subsumes the FFN role
    if cfg.ffn_gelu:
        return 2 * d * cfg.d_ff + d  # GELU: up+down, + norm
    return 3 * d * cfg.d_ff + d  # SwiGLU: gate+up+down, + norm


def _ssm_params(cfg: ArchConfig, d: int) -> int:
    s = cfg.ssm
    di = s.d_inner(d)
    h = s.n_heads(d)
    in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + h)  # x, z, B, C, dt
    out_proj = di * d
    extras = di * s.conv_dim + 3 * h + di + d  # conv, A/dt_bias/D, norms
    return in_proj + out_proj + extras


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (dry-run; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for the given (arch, shape) cell.

    train  -> tokens/labels [B, S]
    prefill-> tokens [B, S]
    decode -> tokens [B, 1] + positions [B] (the KV cache / SSM state is a
              separate argument produced by cache_specs()).
    Modality frontends are stubs: precomputed frame/patch embeddings.
    """
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    i32 = jnp.int32
    if sh["kind"] == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif sh["kind"] == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode: one new token against a cache of length s
        out = {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "positions": jax.ShapeDtypeStruct((b,), i32),
        }
    if cfg.cross_attn is not None and cfg.family == "vlm":
        cd = cfg.cross_attn.ctx_dim or cfg.d_model
        out["ctx_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.cross_attn.n_ctx_tokens, cd), cfg.dtype)
    if cfg.encoder is not None:
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "qwen3_32b", "starcoder2_15b", "qwen3_8b", "qwen1_5_110b", "whisper_medium",
    "llama3_2_vision_11b", "mamba2_2_7b", "moonshot_v1_16b_a3b",
    "llama4_scout_17b_a16e", "jamba_v0_1_52b",
]


def _load_all() -> None:
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def all_cells() -> List[Tuple[str, str]]:
    """Every (arch, shape) cell in the assignment — including skip-annotated ones."""
    _load_all()
    cells = []
    for name in sorted(_REGISTRY):
        for shape in SHAPES:
            cells.append((name, shape))
    return cells
