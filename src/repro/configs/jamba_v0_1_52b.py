"""jamba-v0.1-52b — hybrid, 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Mamba+attention 1:7 interleave (1 attention layer per period of 8), MoE 16e top-2
every other layer. Sub-quadratic overall: long_500k applies.
[arXiv:2403.19887; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

JAMBA_V0_1_52B = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    attn_every=8,          # 1:7 attention:mamba
    attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every=2, offset=1),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=256),
    source="arXiv:2403.19887; hf",
))
