"""llama-3.2-vision-11b — vlm, 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Cross-attn image layers every 5th layer; vision tower is a STUB:
input_specs() provides precomputed patch embeddings (batch, 1601, d_model).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ArchConfig, CrossAttnConfig, register

LLAMA32_VISION_11B = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    rope_theta=5e5,
    cross_attn=CrossAttnConfig(every=5, offset=3, n_ctx_tokens=1601, ctx_dim=0),
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
))
