"""mamba2-2.7b — attention-free SSM, 64L d_model=2560 vocab=50280, ssm_state=128.

SSD (state-space duality). Sub-quadratic: long_500k applies.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig, SSMConfig, register

MAMBA2_2_7B = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,           # attention-free
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
