"""Elastic virtual KV cache pool — the TPU/allocator-level analogue of
CUDA-VMM kvcached (§III.C "spatial multiplexing").

TPU has no user-visible virtual-memory remap, so elasticity is implemented at
the allocator: a shared arena of fixed-size KV pages; each colocated model
advertises a VIRTUAL budget (sum of virtual budgets may exceed physical — the
paper's 3.05x overcommit of Table V), while PHYSICAL pages are granted on
demand under the accountant's admission check. Allocation failure is a signal
(reject / degrade), never an OOM.

The pure-python pool here is the accounting + page-table layer. The
array-backed store that physically holds K/V is
:class:`repro.serving.kv_arena.KVArena`: a
:class:`~repro.serving.kv_arena.ModelKVBinding` mirrors every page grant of
this pool 1:1 onto an arena plane row (mapped on ``alloc_seq``/
``extend_seq``, returned on ``free_seq`` + ``reclaim_unmapped``), so
admission decisions made against this pool govern real memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.runtime.accounting import MemoryAccountant


@dataclasses.dataclass
class SeqAlloc:
    seq_id: int
    model: str
    pages: List[int]
    tokens: int = 0


class VirtualKVPool:
    def __init__(self, accountant: MemoryAccountant, page_bytes: int,
                 page_tokens: int):
        self.acc = accountant
        self.page_bytes = page_bytes
        self.page_tokens = page_tokens
        self.free_pages: List[int] = []
        self.n_pages = 0          # currently-mapped physical pages
        self._next_id = 0         # monotonic page-id source
        self.seqs: Dict[int, SeqAlloc] = {}
        self.virtual_budget: Dict[str, float] = {}

    # -------------------------------------------------------------- budget
    def set_virtual_budget(self, model: str, nbytes: float) -> None:
        self.virtual_budget[model] = nbytes

    def virtual_total(self) -> float:
        return sum(self.virtual_budget.values())

    def overcommit_ratio(self) -> float:
        """(virtual KV + reserved) / physical — Table V's 3.05x metric."""
        return ((self.virtual_total() + self.acc.m_res) /
                max(self.acc.m_total, 1e-9))

    def model_virtual_used(self, model: str) -> float:
        return sum(len(s.pages) for s in self.seqs.values()
                   if s.model == model) * self.page_bytes

    # ------------------------------------------------------------- physical
    def _grow(self, n: int) -> bool:
        """Map n new physical pages (admission-checked)."""
        need = n * self.page_bytes
        if not self.acc.can_admit(need):
            return False
        self.acc.admit_kv(need)
        self.free_pages.extend(range(self._next_id, self._next_id + n))
        self._next_id += n
        self.n_pages += n
        return True

    def alloc_seq(self, seq_id: int, model: str, tokens: int) -> bool:
        """Admit a sequence needing `tokens` of KV; grants pages on demand."""
        n = max(1, -(-tokens // self.page_tokens))
        if (self.model_virtual_used(model) + n * self.page_bytes
                > self.virtual_budget.get(model, float("inf"))):
            return False
        if len(self.free_pages) < n and not self._grow(n - len(self.free_pages)):
            return False
        pages = [self.free_pages.pop() for _ in range(n)]
        self.seqs[seq_id] = SeqAlloc(seq_id, model, pages, tokens)
        return True

    def extend_seq(self, seq_id: int, new_tokens: int) -> bool:
        """Grow a sequence's KV as it decodes (on-demand page mapping)."""
        s = self.seqs[seq_id]
        total = s.tokens + new_tokens
        need = max(0, -(-total // self.page_tokens) - len(s.pages))
        if need:
            if len(self.free_pages) < need and \
                    not self._grow(need - len(self.free_pages)):
                return False
            s.pages.extend(self.free_pages.pop() for _ in range(need))
        s.tokens = total
        return True

    def free_seq(self, seq_id: int) -> None:
        s = self.seqs.pop(seq_id, None)
        if s is None:
            return
        self.free_pages.extend(s.pages)

    def reclaim_unmapped(self) -> float:
        """Unmap free pages back to the accountant (elastic shrink)."""
        freed = len(self.free_pages) * self.page_bytes
        # compact: renumber is unnecessary for accounting purposes
        self.acc.release_kv(freed)
        self.n_pages -= len(self.free_pages)
        self.free_pages.clear()
        return freed

    # ------------------------------------------------------------- metrics
    def physical_used(self) -> float:
        return (self.n_pages - len(self.free_pages)) * self.page_bytes

    def fragmentation(self) -> float:
        """Internal fragmentation: allocated-but-unused token slots."""
        alloc_tokens = sum(len(s.pages) for s in self.seqs.values()) \
            * self.page_tokens
        used_tokens = sum(s.tokens for s in self.seqs.values())
        return 1.0 - used_tokens / alloc_tokens if alloc_tokens else 0.0
