"""Hierarchical weight residency + tiered LRU eviction (§III.C, Algorithm 1).

Model readiness states:
  RUNNING      — weights + execution context resident on the accelerator
  SLEEPING     — weights offloaded to host, warm context retained on-device
                 (compiled-executable cache — the CUDA-graph analogue)
  CPU          — weights cached in host memory, no device context
  DISK         — weights on local disk
  REMOTE       — must be fetched from remote storage

Activation latency is a profiled bandwidth model: T_act ~ size / BW_tier,
summed over the tiers crossed (Remote->Disk->CPU->GPU), plus a re-trace cost
when no warm context survives.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

from repro.core.predictor.cost_model import HardwareSpec, ModelProfile


class ModelState(enum.Enum):
    RUNNING = "running"
    SLEEPING = "sleeping"
    CPU = "cpu"
    DISK = "disk"
    REMOTE = "remote"


# activation path: state -> list of (bw attribute, bytes multiplier)
_TIER_ORDER = [ModelState.RUNNING, ModelState.SLEEPING, ModelState.CPU,
               ModelState.DISK, ModelState.REMOTE]

RETRACE_COST_S = 1.5   # compile/re-trace when no warm context is retained


@dataclasses.dataclass
class ResidencyEvent:
    kind: str           # load | evict_to_cpu | evict_to_disk | drop
    model: str
    tier: str
    seconds: float


class HierarchicalResidency:
    """Algorithm 1 — cascading load-and-evict across GPU/CPU/disk tiers."""

    def __init__(self, profiles: Dict[str, ModelProfile],
                 c_gpu: float, c_cpu: float, c_disk: float,
                 hw: Optional[HardwareSpec] = None):
        self.profiles = profiles
        self.cap = {"gpu": c_gpu, "cpu": c_cpu, "disk": c_disk}
        self.hw = hw or next(iter(profiles.values())).hw if profiles else HardwareSpec()
        self.state: Dict[str, ModelState] = {
            m: ModelState.REMOTE for m in profiles}
        self.pinned: set = set()   # models that may not be evicted (in-flight)
        # LRU per tier: ordered dict model -> bytes (front = LRU)
        self.lru: Dict[str, "collections.OrderedDict[str, int]"] = {
            "gpu": collections.OrderedDict(),
            "cpu": collections.OrderedDict(),
            "disk": collections.OrderedDict(),
        }
        self.events: List[ResidencyEvent] = []

    # ------------------------------------------------------------- helpers
    def used(self, tier: str) -> int:
        return sum(self.lru[tier].values())

    def size(self, m: str) -> int:
        return self.profiles[m].weight_bytes

    def touch(self, tier: str, m: str) -> None:
        self.lru[tier][m] = self.lru[tier].pop(m, self.size(m))

    def _remove(self, tier: str, m: str) -> None:
        self.lru[tier].pop(m, None)

    # -------------------------------------------------- activation estimate
    def activation_latency(self, m: str) -> float:
        """T_act ~ sum(size/BW) over tiers to cross (+ retrace if cold)."""
        st = self.state[m]
        size = self.size(m)
        hw = self.hw
        if st is ModelState.RUNNING:
            return 0.0
        if st is ModelState.SLEEPING:
            return size / hw.host_link_bw         # context warm: reload only
        if st is ModelState.CPU:
            return size / hw.host_link_bw + RETRACE_COST_S
        if st is ModelState.DISK:
            return size / hw.disk_bw + size / hw.host_link_bw + RETRACE_COST_S
        return (size / hw.remote_bw + size / hw.disk_bw
                + size / hw.host_link_bw + RETRACE_COST_S)

    # ------------------------------------------------------- Algorithm 1
    def ensure_gpu(self, m: str) -> Tuple[bool, float]:
        """Make model m GPU-ready; returns (success, activation seconds)."""
        size = self.size(m)
        if size > self.cap["gpu"]:
            return False, 0.0
        t_act = self.activation_latency(m)
        loc = self.state[m]
        if loc is ModelState.RUNNING:
            self.touch("gpu", m)
            return True, 0.0
        # make room on GPU (evict LRU to host, skipping pinned models)
        while self.used("gpu") + size > self.cap["gpu"]:
            victim = next((v for v in self.lru["gpu"]
                           if v not in self.pinned and v != m), None)
            if victim is None:
                return False, 0.0   # everything resident is in-flight
            self._evict_gpu_to_host(victim)
        if loc in (ModelState.DISK, ModelState.REMOTE):
            # make room in host RAM
            while self.used("cpu") + size > self.cap["cpu"]:
                v = next(iter(self.lru["cpu"]))
                self._evict_cpu(v)
            self.lru["cpu"][m] = size
            self._remove("disk", m)
            self.state[m] = ModelState.CPU
        # load to GPU (weights also stay cached in host RAM)
        self.lru["gpu"][m] = size
        self.state[m] = ModelState.RUNNING
        self.events.append(ResidencyEvent("load", m, "gpu", t_act))
        return True, t_act

    def _evict_gpu_to_host(self, m: str) -> None:
        size = self.size(m)
        self._remove("gpu", m)
        while self.used("cpu") + size > self.cap["cpu"]:
            v = next(iter(self.lru["cpu"]))
            if v == m:
                break
            self._evict_cpu(v)
        self.lru["cpu"][m] = size
        self.state[m] = ModelState.SLEEPING
        self.events.append(ResidencyEvent(
            "evict_to_cpu", m, "cpu", size / self.hw.host_link_bw))

    def _evict_cpu(self, m: str) -> None:
        size = self.size(m)
        self._remove("cpu", m)
        if self.used("disk") + size <= self.cap["disk"]:
            self.lru["disk"][m] = size
            self.state[m] = ModelState.DISK
            self.events.append(ResidencyEvent(
                "evict_to_disk", m, "disk", size / self.hw.disk_bw))
        else:
            self.state[m] = ModelState.REMOTE
            self.events.append(ResidencyEvent("drop", m, "remote", 0.0))

    # ----------------------------------------------------------- sleeping
    def sleep(self, m: str) -> None:
        """RUNNING -> SLEEPING (weights offloaded, warm context retained)."""
        if self.state[m] is ModelState.RUNNING:
            self._evict_gpu_to_host(m)

    def demote_context(self, m: str) -> None:
        """SLEEPING -> CPU (drop the warm device context)."""
        if self.state[m] is ModelState.SLEEPING:
            self.state[m] = ModelState.CPU

    def warm_set(self) -> List[str]:
        """Models whose device context is resident (RUNNING or SLEEPING)."""
        return [m for m, s in self.state.items()
                if s in (ModelState.RUNNING, ModelState.SLEEPING)]
