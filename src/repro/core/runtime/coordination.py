"""Minimum-impact memory coordination (§III.C, Algorithm 2 + Eq. 4).

When KV admission fails, derive a degradation plan by walking resident
engines in ascending disruption order and accumulating freed memory.
Five degradation levels:
  1. Idle-RUNNING  -> SLEEPING   (offload weights, keep context)
  2. evict SLEEPING              (drop warm context + host copy stays)
  3. stop pending sleep transitions
  4. swap out KV of ACTIVE engines
  5. abort ACTIVE executions

The plan's total disruption penalty (Eq. 4):
  C_deg = sum c(e, a) + 1[I_active] * c_int
with c(e,a) from profiled storage bandwidth (weight reload) or compute
throughput (KV regeneration), and c_int the SLO-violation charge.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

from repro.core.predictor.cost_model import HardwareSpec


class EngineState(enum.Enum):
    IDLE = "idle"            # RUNNING but no in-flight request
    SLEEPING = "sleeping"
    PENDING_SLEEP = "pending_sleep"
    ACTIVE = "active"


class Action(enum.Enum):
    SLEEP = "sleep"                  # level 1
    EVICT_SLEEPING = "evict"         # level 2
    CANCEL_SLEEP = "cancel_sleep"    # level 3
    SWAP_KV = "swap_kv"              # level 4
    ABORT = "abort"                  # level 5


_PRIORITY = {EngineState.IDLE: 0, EngineState.SLEEPING: 1,
             EngineState.PENDING_SLEEP: 2, EngineState.ACTIVE: 3}


@dataclasses.dataclass
class EngineInfo:
    model: str
    state: EngineState
    weight_bytes: float
    ctx_bytes: float
    kv_bytes: float = 0.0
    kv_tokens: int = 0
    decode_tok_per_s: float = 50.0       # for KV regeneration cost


@dataclasses.dataclass
class DegradationPlan:
    steps: List[Tuple[EngineInfo, Action]]
    freed: float
    interrupts_active: bool
    c_deg: float

    @property
    def feasible(self) -> bool:
        return bool(self.steps) or self.freed > 0


def _best_action(e: EngineInfo) -> Tuple[Optional[Action], float]:
    """(action, freed bytes) for an engine by its state (level ordering)."""
    if e.state is EngineState.IDLE:
        return Action.SLEEP, e.weight_bytes
    if e.state is EngineState.SLEEPING:
        return Action.EVICT_SLEEPING, e.ctx_bytes
    if e.state is EngineState.PENDING_SLEEP:
        return Action.CANCEL_SLEEP, e.weight_bytes
    if e.state is EngineState.ACTIVE:
        if e.kv_bytes > 0:
            return Action.SWAP_KV, e.kv_bytes
        return Action.ABORT, e.weight_bytes + e.kv_bytes
    return None, 0.0


def action_cost(e: EngineInfo, a: Action, hw: HardwareSpec) -> float:
    """c(e, a): restoration latency of undoing the degradation."""
    if a is Action.SLEEP or a is Action.CANCEL_SLEEP:
        return e.weight_bytes / hw.host_link_bw
    if a is Action.EVICT_SLEEPING:
        # context must be re-traced + weights re-staged later
        return e.weight_bytes / hw.host_link_bw + 1.5
    if a is Action.SWAP_KV:
        # KV regeneration: recompute kv_tokens at decode throughput
        return e.kv_tokens / max(e.decode_tok_per_s, 1e-9)
    if a is Action.ABORT:
        return e.kv_tokens / max(e.decode_tok_per_s, 1e-9) + 1.5
    return 0.0


def plan_degradation(required: float, engines: List[EngineInfo],
                     hw: HardwareSpec, c_int: float = 5.0
                     ) -> Optional[DegradationPlan]:
    """Algorithm 2. Returns None when even full degradation cannot free
    ``required`` bytes (the scheduler then reports infeasibility)."""
    freed = 0.0
    steps: List[Tuple[EngineInfo, Action]] = []
    interrupts = False
    c_deg = 0.0
    for e in sorted(engines, key=lambda e: _PRIORITY[e.state]):
        if freed >= required:
            break
        a, f = _best_action(e)
        if a is None or f <= 0:
            continue
        if a in (Action.SWAP_KV, Action.ABORT):
            interrupts = True
        freed += f
        c_deg += action_cost(e, a, hw)
        steps.append((e, a))
    if freed < required:
        return None
    if interrupts:
        c_deg += c_int
    return DegradationPlan(steps, freed, interrupts, c_deg)
