"""Explicit GPU memory accounting with admission-time feasibility checks
(§III.C): M_kv + M_res <= M_total, where M_res = sum(M_ctx^k) + M_other.

The accountant is the single source of truth the node runtime, the KV pool
and the scheduler all read; the KV admission headroom R_kv_head(N) it exports
is the routing signal of Eq. 5's affinity term.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


class AdmissionError(Exception):
    pass


@dataclasses.dataclass
class MemoryAccountant:
    m_total: float                       # total device memory for the runtime
    m_other: float = 0.0                 # non-model overheads
    m_kv: float = 0.0                    # current KV usage
    ctx: Dict[str, float] = dataclasses.field(default_factory=dict)
    weights: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def m_res(self) -> float:
        """Reserved non-KV footprint: warm contexts + resident weights + other."""
        return sum(self.ctx.values()) + sum(self.weights.values()) + self.m_other

    @property
    def headroom(self) -> float:
        """R_kv_head(N) = M_total - M_res - M_kv."""
        return self.m_total - self.m_res - self.m_kv

    def check_invariant(self) -> bool:
        return self.m_kv + self.m_res <= self.m_total + 1e-6

    # ------------------------------------------------------------ mutation
    def register_context(self, model: str, nbytes: float) -> None:
        self.ctx[model] = nbytes

    def unregister_context(self, model: str) -> None:
        self.ctx.pop(model, None)

    def register_weights(self, model: str, nbytes: float) -> None:
        self.weights[model] = nbytes

    def unregister_weights(self, model: str) -> None:
        self.weights.pop(model, None)

    def can_admit(self, r_need: float) -> bool:
        return r_need <= self.headroom

    def admit_kv(self, r_need: float) -> None:
        if not self.can_admit(r_need):
            raise AdmissionError(
                f"KV admission of {r_need/1e9:.2f}GB exceeds headroom "
                f"{self.headroom/1e9:.2f}GB")
        self.m_kv += r_need

    def release_kv(self, nbytes: float) -> None:
        self.m_kv = max(0.0, self.m_kv - nbytes)
