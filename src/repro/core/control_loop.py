"""The five-phase stage-driven closed-loop control pipeline (§III.A):

  1. agent-context observation   -> StageObservation
  2. cost prediction             -> L_hat, R_kv_hat, p_tool (dispatch gateway)
  3. scheduling decision         -> fitness routing + SRTF queueing
  4. node-level execution        -> residency / accounting / coordination
  5. post-execution profiling    -> predictor calibration (rho, Eq.8 profiles)

``MaestroController`` wires the core components; the discrete-event simulator
(repro.sim) and the real serving engine (repro.serving) both drive it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.predictor.cost_model import ModelProfile
from repro.core.predictor.features import StageObservation
from repro.core.predictor.length_model import MaestroPred
from repro.core.sched.fitness import (FitnessRouter, FitnessWeights,
                                      NodeSignal, StageRequest)
from repro.core.sched.margins import RhoEstimator
from repro.core.sched.srtf import (QueuedStage, SRTFQueue,
                                   WorkflowProfileStore, state_key)


@dataclasses.dataclass
class StagePlan:
    stage_id: int
    node_id: Optional[int]
    score: float
    l_hat: float
    p_tool: float
    r_kv_hat: float
    r_need: float
    t_exec: float
    t_future: float


class MaestroController:
    def __init__(self, predictor: MaestroPred,
                 profiles: Dict[str, ModelProfile],
                 rtt_s: np.ndarray,
                 weights: Optional[FitnessWeights] = None,
                 gamma: float = 0.25,
                 queue: Optional[SRTFQueue] = None):
        self.predictor = predictor
        self.profiles = profiles
        self.router = FitnessRouter(rtt_s, weights, gamma=gamma)
        self.rho = RhoEstimator()
        # callers operating at a different time scale (e.g. the live gateway's
        # tick clock) pass a queue with matching hysteresis thresholds
        self.queue = queue if queue is not None else SRTFQueue()
        self.wf_profiles = WorkflowProfileStore()

    # ------------------------------------------------------------ phase 1+2
    def predict_stage(self, obs: StageObservation) -> Tuple[float, float, float]:
        """Returns (L_hat, p_tool, R_kv_hat)."""
        pred = self.predictor.predict_one(obs)
        prof = self.profiles[model_name(obs, self.profiles)]
        r_kv = prof.r_kv(obs.prompt_len, pred["length"])
        return pred["length"], pred["p_tool"], r_kv

    # -------------------------------------------------------------- phase 3
    def plan(self, stage_id: int, job_id: int, obs: StageObservation,
             interactive: bool, nodes: List[NodeSignal],
             t_act_of, c_deg_of, now: float = 0.0) -> StagePlan:
        l_hat, p_tool, r_kv_hat = self.predict_stage(obs)
        prof = self.profiles[model_name(obs, self.profiles)]
        t_exec = prof.t_exec(obs.prompt_len, l_hat)
        r_need = self.rho.r_need(r_kv_hat)
        req = StageRequest(stage_id=stage_id,
                           model=prof.name, r_need=r_need,
                           interactive=interactive,
                           src_cluster=obs.src_cluster, t_exec=t_exec)
        sel = self.router.select(req, nodes, t_act_of, c_deg_of)
        key = state_key(obs.app, obs.role, obs.invocation_idx, p_tool)
        t_future = self.wf_profiles.future_median(key)
        return StagePlan(
            stage_id=stage_id,
            node_id=None if sel is None else sel[0].node_id,
            score=-np.inf if sel is None else sel[1],
            l_hat=l_hat, p_tool=p_tool, r_kv_hat=r_kv_hat, r_need=r_need,
            t_exec=t_exec, t_future=t_future)

    def enqueue(self, plan: StagePlan, job_id: int, interactive: bool,
                now: float) -> QueuedStage:
        qs = QueuedStage(stage_id=plan.stage_id, job_id=job_id,
                         interactive=interactive, t_exec=plan.t_exec,
                         t_future=plan.t_future, enqueue_time=now)
        self.queue.push(qs, now)
        return qs

    # -------------------------------------------------------------- phase 5
    def observe_completion(self, obs: StageObservation, plan: StagePlan,
                           actual_len: float, actual_kv: float,
                           job_remaining_after_s: float) -> None:
        """Post-execution profiling: calibrate rho + Eq. 8 profiles."""
        self.rho.observe(actual_kv, max(plan.r_kv_hat, 1.0))
        key = state_key(obs.app, obs.role, obs.invocation_idx, plan.p_tool)
        self.wf_profiles.record(key, job_remaining_after_s)


def model_name(obs: StageObservation, profiles: Dict[str, ModelProfile]) -> str:
    """Deterministic model assignment shared by every plane that consumes the
    controller: observation model ids map onto the sorted profile names, so
    predictions, routing and live execution all agree on the serving model."""
    names = sorted(profiles)
    return names[obs.model_id % len(names)]


_model_name = model_name  # backwards-compatible alias
