"""Translation of predicted output length to system metrics (§III.B, Eq. 2-3)
via calibrated per-model profiles.

    T_exec(T) = t_pre(P, M) + t_dec(M) * L_hat          (Eq. 2)
    R_kv(T)   = alpha(M) * (P + L_hat)                  (Eq. 3)

Profiles come from the dry-run roofline (the "per-model microbenchmarks" the
paper assumes): prefill is compute-bound (2*N_active*P / chip peak), decode is
memory-bound (weights + KV read per token / HBM bandwidth). ``profile_from_arch``
derives them analytically for any ArchConfig on any accelerator spec; the
simulator and the serving engine consume the same objects.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16
    hbm_bw: float = 819e9            # bytes/s
    hbm_capacity: float = 16e9
    host_link_bw: float = 32e9       # host<->device staging
    disk_bw: float = 3e9
    remote_bw: float = 1e9
    mfu: float = 0.5                 # realized fraction of peak in prefill
    mbu: float = 0.7                 # realized fraction of HBM bw in decode


A100_40G = HardwareSpec(name="a100-40g", peak_flops=312e12, hbm_bw=1555e9,
                        hbm_capacity=40e9, host_link_bw=25e9)


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Calibrated per-model microbenchmark (Eq. 2-3 inputs)."""
    name: str
    weight_bytes: int
    ctx_bytes: int                   # persistent warm context (M_ctx^k)
    alpha_bytes_per_token: int       # KV footprint per token (Eq. 3)
    state_bytes: int                 # constant per-seq state (SSM archs)
    prefill_flops_per_token: float
    decode_bytes_per_token: float    # HBM bytes read per generated token
    hw: HardwareSpec

    def t_prefill(self, prompt_len: int) -> float:
        return (prompt_len * self.prefill_flops_per_token
                / (self.hw.peak_flops * self.hw.mfu))

    @property
    def t_decode(self) -> float:
        """Seconds per generated token (batch-1 lower bound)."""
        return self.decode_bytes_per_token / (self.hw.hbm_bw * self.hw.mbu)

    def t_exec(self, prompt_len: int, pred_len: float) -> float:
        """Eq. 2."""
        return self.t_prefill(prompt_len) + self.t_decode * pred_len

    def r_kv(self, prompt_len: int, pred_len: float) -> float:
        """Eq. 3 (+ constant recurrent state for SSM/hybrid)."""
        return (self.alpha_bytes_per_token * (prompt_len + pred_len)
                + self.state_bytes)


def profile_from_arch(cfg: ArchConfig, hw: HardwareSpec = HardwareSpec(),
                      ctx_bytes: int = 256 << 20) -> ModelProfile:
    n_active = cfg.active_param_count()
    alpha = cfg.kv_bytes_per_token()
    return ModelProfile(
        name=cfg.name,
        weight_bytes=cfg.weight_bytes(),
        ctx_bytes=ctx_bytes,
        alpha_bytes_per_token=alpha,
        state_bytes=cfg.ssm_state_bytes(),
        prefill_flops_per_token=2.0 * n_active,
        # decode reads active weights once per token + amortized KV walk
        decode_bytes_per_token=2.0 * n_active + alpha * 1024,
        hw=hw,
    )


def synthetic_profile(name: str, params_b: float,
                      hw: HardwareSpec = HardwareSpec(),
                      n_layers: int = 32, n_kv: int = 8, head_dim: int = 128,
                      ctx_bytes: int = 200 << 20) -> ModelProfile:
    """Profile for a model named only by size (the sim's small Qwen3 zoo)."""
    n = params_b * 1e9
    alpha = int(n_layers * 2 * n_kv * head_dim * 2)
    return ModelProfile(
        name=name, weight_bytes=int(2 * n), ctx_bytes=ctx_bytes,
        alpha_bytes_per_token=alpha, state_bytes=0,
        prefill_flops_per_token=2.0 * n,
        decode_bytes_per_token=2.0 * n + alpha * 1024, hw=hw)
