from repro.core.predictor.cost_model import (A100_40G, HardwareSpec,
                                             ModelProfile, profile_from_arch,
                                             synthetic_profile)
from repro.core.predictor.features import (StageObservation, featurize,
                                           featurize_batch,
                                           semantic_embedding)
from repro.core.predictor.gbdt import GBDT, GBDTConfig
from repro.core.predictor.isotonic import IsotonicCalibrator
from repro.core.predictor.length_model import (BertMLPBaseline,
                                               LinearBaseline, MLP,
                                               MaestroPred, MagnusBaseline,
                                               PredictorConfig,
                                               classification_metrics,
                                               regression_metrics)
