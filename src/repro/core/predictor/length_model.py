"""Output-length prediction (§III.B) — Maestro-Pred and the paper's baselines.

Maestro-Pred (two-phase):
  1. tool-intent classifier (GBDT on structured + semantic features),
     isotonic-calibrated -> p_tool(T)  (Eq. 1)
  2. length regressors on log1p(L): per-role when the role has enough
     training data, else a shared global model; p_tool is an input feature.

Baselines (§IV.A):
  Linear    — prompt-length-only least squares
  BERT-MLP  — semantic embedding + MLP, single stage
  Magnus    — semantic embedding + GBDT regression, single stage
Ablations: w/o C (no classifier), w/o BERT (no semantic features).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.predictor.features import (N_STRUCT, StageObservation,
                                           featurize_batch)
from repro.core.predictor.gbdt import GBDT, GBDTConfig
from repro.core.predictor.isotonic import IsotonicCalibrator

MIN_ROLE_SAMPLES = 200


@dataclasses.dataclass
class PredictorConfig:
    use_classifier: bool = True       # ablation: w/o C
    use_semantic: bool = True         # ablation: w/o BERT
    per_role: bool = True
    cls: GBDTConfig = dataclasses.field(default_factory=lambda: GBDTConfig(
        objective="logloss", n_trees=120, max_leaves=31))
    reg: GBDTConfig = dataclasses.field(default_factory=lambda: GBDTConfig(
        objective="l2", n_trees=150, max_leaves=31))


class MaestroPred:
    """Two-phase agent-aware cost predictor."""

    def __init__(self, cfg: Optional[PredictorConfig] = None):
        self.cfg = cfg or PredictorConfig()
        self.clf: Optional[GBDT] = None
        self.cal: Optional[IsotonicCalibrator] = None
        self.regs: Dict[int, GBDT] = {}       # per-role; -1 = global
        self._roles: List[int] = []

    # -- phase 1 -------------------------------------------------------
    def predict_tool(self, X: np.ndarray, tools_avail: np.ndarray) -> np.ndarray:
        if self.clf is None:
            return np.zeros(len(X))
        p = self.clf.predict(X)
        if self.cal is not None:
            p = self.cal.transform(p)
        return np.where(tools_avail > 0, p, 0.0)  # no tools => p_tool = 0

    # -- training ------------------------------------------------------
    def fit(self, observations: List[StageObservation], lengths: np.ndarray,
            tool_labels: np.ndarray, val_frac: float = 0.15) -> "MaestroPred":
        X = featurize_batch(observations, semantic=self.cfg.use_semantic)
        y = np.log1p(np.asarray(lengths, np.float64))
        roles = np.array([o.role for o in observations])
        tools_avail = np.array([o.tools_available for o in observations])
        n = len(X)
        n_val = max(1, int(n * val_frac))
        tr, va = slice(0, n - n_val), slice(n - n_val, n)  # temporal split

        if self.cfg.use_classifier:
            self.clf = GBDT(self.cfg.cls).fit(
                X[tr], tool_labels[tr], X[va], tool_labels[va])
            raw = self.clf.predict(X[va])
            self.cal = IsotonicCalibrator().fit(raw, tool_labels[va])
            p_tool = self.predict_tool(X, tools_avail)
            Xr = np.concatenate([X, p_tool[:, None]], axis=1)
        else:
            Xr = X

        self.regs[-1] = GBDT(self.cfg.reg).fit(Xr[tr], y[tr], Xr[va], y[va])
        if self.cfg.per_role:
            for r in np.unique(roles):
                m = roles == r
                mt = m.copy()
                mt[va] = False
                mv = m.copy()
                mv[tr] = False
                if mt.sum() >= MIN_ROLE_SAMPLES:
                    self.regs[int(r)] = GBDT(self.cfg.reg).fit(
                        Xr[mt], y[mt],
                        Xr[mv] if mv.sum() else None,
                        y[mv] if mv.sum() else None)
        self._roles = sorted(k for k in self.regs if k >= 0)
        return self

    # -- inference -----------------------------------------------------
    def predict(self, observations: List[StageObservation]) -> Dict[str, np.ndarray]:
        X = featurize_batch(observations, semantic=self.cfg.use_semantic)
        roles = np.array([o.role for o in observations])
        tools_avail = np.array([o.tools_available for o in observations])
        p_tool = (self.predict_tool(X, tools_avail)
                  if self.cfg.use_classifier else np.zeros(len(X)))
        Xr = (np.concatenate([X, p_tool[:, None]], axis=1)
              if self.cfg.use_classifier else X)
        out = np.empty(len(X))
        done = np.zeros(len(X), bool)
        for r in self._roles:
            m = (roles == r) & ~done
            if m.any():
                out[m] = self.regs[r].raw_predict(Xr[m])
                done |= m
        if (~done).any():
            out[~done] = self.regs[-1].raw_predict(Xr[~done])
        return {"length": np.expm1(out).clip(1, None), "p_tool": p_tool}

    def predict_one(self, obs: StageObservation) -> Dict[str, float]:
        r = self.predict([obs])
        return {"length": float(r["length"][0]), "p_tool": float(r["p_tool"][0])}


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

class LinearBaseline:
    """Prompt-length-only OLS (the paper's 'Linear')."""

    def fit(self, observations, lengths, tool_labels=None):
        x = np.array([o.prompt_len for o in observations], np.float64)
        y = np.asarray(lengths, np.float64)
        A = np.stack([x, np.ones_like(x)], axis=1)
        self.w, *_ = np.linalg.lstsq(A, y, rcond=None)
        return self

    def predict(self, observations):
        x = np.array([o.prompt_len for o in observations], np.float64)
        return {"length": (self.w[0] * x + self.w[1]).clip(1, None)}


class MLP:
    """Small numpy MLP (Adam, ReLU) — backbone of the BERT-MLP baseline and
    the neural tool-intent baselines in Table III."""

    def __init__(self, hidden=(64, 32), lr=1e-3, epochs=60, batch=256,
                 classifier=False, seed=0):
        self.hidden, self.lr, self.epochs = hidden, lr, epochs
        self.batch, self.classifier = batch, classifier
        self.rng = np.random.default_rng(seed)
        self.Ws: List[np.ndarray] = []
        self.bs: List[np.ndarray] = []

    def _init(self, d_in):
        dims = [d_in, *self.hidden, 1]
        self.Ws = [self.rng.normal(0, np.sqrt(2.0 / dims[i]),
                                   (dims[i], dims[i + 1]))
                   for i in range(len(dims) - 1)]
        self.bs = [np.zeros(dims[i + 1]) for i in range(len(dims) - 1)]

    def _forward(self, X):
        acts = [X]
        h = X
        for i, (W, b) in enumerate(zip(self.Ws, self.bs)):
            h = h @ W + b
            if i < len(self.Ws) - 1:
                h = np.maximum(h, 0)
            acts.append(h)
        return acts

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64).reshape(-1, 1)
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-8
        X = (X - self.mu) / self.sd
        self._init(X.shape[1])
        mW = [np.zeros_like(W) for W in self.Ws]
        vW = [np.zeros_like(W) for W in self.Ws]
        mb = [np.zeros_like(b) for b in self.bs]
        vb = [np.zeros_like(b) for b in self.bs]
        t = 0
        for _ in range(self.epochs):
            order = self.rng.permutation(len(X))
            for s in range(0, len(X), self.batch):
                idx = order[s:s + self.batch]
                acts = self._forward(X[idx])
                out = acts[-1]
                if self.classifier:
                    p = 1 / (1 + np.exp(-out))
                    delta = (p - y[idx]) / len(idx)
                else:
                    delta = (out - y[idx]) / len(idx)
                t += 1
                for i in reversed(range(len(self.Ws))):
                    gW = acts[i].T @ delta
                    gb = delta.sum(0)
                    if i > 0:
                        delta = (delta @ self.Ws[i].T) * (acts[i] > 0)
                    for g, w, m, v in ((gW, self.Ws[i], mW[i], vW[i]),
                                       (gb, self.bs[i], mb[i], vb[i])):
                        m *= 0.9
                        m += 0.1 * g
                        v *= 0.999
                        v += 0.001 * g * g
                        mh = m / (1 - 0.9 ** t)
                        vh = v / (1 - 0.999 ** t)
                        w -= self.lr * mh / (np.sqrt(vh) + 1e-8)
        return self

    def predict(self, X):
        X = (np.asarray(X, np.float64) - self.mu) / self.sd
        out = self._forward(X)[-1][:, 0]
        if self.classifier:
            return 1 / (1 + np.exp(-out))
        return out


class BertMLPBaseline:
    """Semantic embedding + single-stage MLP regression on log1p(L)."""

    def __init__(self, hidden=(64, 32)):
        self.mlp = MLP(hidden=hidden)

    def fit(self, observations, lengths, tool_labels=None):
        X = featurize_batch(observations, semantic=True)
        self.mlp.fit(X, np.log1p(np.asarray(lengths, np.float64)))
        return self

    def predict(self, observations):
        X = featurize_batch(observations, semantic=True)
        return {"length": np.expm1(self.mlp.predict(X)).clip(1, None)}


class MagnusBaseline:
    """Semantic embedding + single-stage GBDT regression (Magnus-style)."""

    def __init__(self, cfg: Optional[GBDTConfig] = None):
        self.reg = GBDT(cfg or GBDTConfig(objective="l2", n_trees=150))

    def fit(self, observations, lengths, tool_labels=None, val_frac=0.15):
        X = featurize_batch(observations, semantic=True)
        y = np.log1p(np.asarray(lengths, np.float64))
        n_val = max(1, int(len(X) * val_frac))
        self.reg.fit(X[:-n_val], y[:-n_val], X[-n_val:], y[-n_val:])
        return self

    def predict(self, observations):
        X = featurize_batch(observations, semantic=True)
        return {"length": np.expm1(self.reg.raw_predict(X)).clip(1, None)}


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def regression_metrics(y_true, y_pred) -> Dict[str, float]:
    y_true = np.asarray(y_true, np.float64)
    y_pred = np.asarray(y_pred, np.float64)
    mae = float(np.mean(np.abs(y_true - y_pred)))
    ss_res = float(np.sum((y_true - y_pred) ** 2))
    ss_tot = float(np.sum((y_true - y_true.mean()) ** 2))
    return {"mae": mae, "r2": 1.0 - ss_res / max(ss_tot, 1e-12)}


def classification_metrics(y_true, p) -> Dict[str, float]:
    y = np.asarray(y_true, np.float64)
    p = np.clip(np.asarray(p, np.float64), 1e-12, 1 - 1e-12)
    # AUC via rank statistic
    order = np.argsort(p)
    ranks = np.empty(len(p))
    ranks[order] = np.arange(1, len(p) + 1)
    n1, n0 = y.sum(), (1 - y).sum()
    auc = ((ranks[y == 1].sum() - n1 * (n1 + 1) / 2) / max(n1 * n0, 1e-12))
    pred = (p >= 0.5).astype(float)
    acc = float(np.mean(pred == y))
    tp = float(((pred == 1) & (y == 1)).sum())
    fp = float(((pred == 1) & (y == 0)).sum())
    fn = float(((pred == 0) & (y == 1)).sum())
    tn = float(((pred == 0) & (y == 0)).sum())
    prec1 = tp / max(tp + fp, 1e-12)
    rec1 = tp / max(tp + fn, 1e-12)
    f1_1 = 2 * prec1 * rec1 / max(prec1 + rec1, 1e-12)
    prec0 = tn / max(tn + fn, 1e-12)
    rec0 = tn / max(tn + fp, 1e-12)
    f1_0 = 2 * prec0 * rec0 / max(prec0 + rec0, 1e-12)
    return {
        "auc": float(auc), "acc": acc, "f1_macro": (f1_1 + f1_0) / 2,
        "mse": float(np.mean((p - y) ** 2)),
        "logloss": float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p))),
        "neg_recall": rec0,
    }
