"""Isotonic regression (pool-adjacent-violators) — calibrates the tool-intent
classifier's scores so predicted confidence matches empirical frequency
(§III.B, Eq. 1)."""
from __future__ import annotations

import numpy as np


class IsotonicCalibrator:
    def __init__(self):
        self.x_: np.ndarray = np.array([0.0, 1.0])
        self.y_: np.ndarray = np.array([0.0, 1.0])

    def fit(self, scores: np.ndarray, labels: np.ndarray) -> "IsotonicCalibrator":
        order = np.argsort(scores, kind="stable")
        x = np.asarray(scores, np.float64)[order]
        y = np.asarray(labels, np.float64)[order]
        # PAVA with block weights
        vals = list(y)
        wts = [1.0] * len(y)
        starts = list(range(len(y)))
        i = 0
        out_v, out_w, out_s = [], [], []
        for v, w, s in zip(vals, wts, starts):
            out_v.append(v)
            out_w.append(w)
            out_s.append(s)
            while len(out_v) > 1 and out_v[-2] > out_v[-1]:
                v2, w2 = out_v.pop(), out_w.pop()
                out_s.pop()
                out_v[-1] = (out_v[-1] * out_w[-1] + v2 * w2) / (out_w[-1] + w2)
                out_w[-1] += w2
        # expand blocks to breakpoints
        xs, ys = [], []
        bounds = out_s + [len(y)]
        for b in range(len(out_v)):
            lo, hi = bounds[b], bounds[b + 1]
            xs.append(x[lo])
            ys.append(out_v[b])
            xs.append(x[hi - 1])
            ys.append(out_v[b])
        self.x_ = np.array(xs)
        self.y_ = np.array(ys)
        return self

    def transform(self, scores: np.ndarray) -> np.ndarray:
        return np.interp(np.asarray(scores, np.float64), self.x_, self.y_,
                         left=self.y_[0], right=self.y_[-1])
