"""Histogram gradient-boosted decision trees (LightGBM stand-in, pure numpy).

Same algorithm class as the paper's predictor: leaf-wise growth with a
max-leaves budget, 256-bin feature histograms, second-order (grad/hess)
splits with L2 regularization, early stopping on a validation split.
Supports squared-error regression and binary logloss classification.

The histogram trick: per node, one vectorized bincount over (feature, bin)
pairs; sibling histograms obtained by parent - left subtraction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class GBDTConfig:
    n_trees: int = 150
    learning_rate: float = 0.1
    max_leaves: int = 31
    min_child_weight: float = 5.0
    reg_lambda: float = 1.0
    n_bins: int = 256
    early_stopping: int = 20
    objective: str = "l2"          # l2 | logloss
    min_gain: float = 1e-6
    seed: int = 0


class _Binner:
    def __init__(self, n_bins: int):
        self.n_bins = n_bins
        self.edges: List[np.ndarray] = []

    def fit(self, X: np.ndarray) -> "_Binner":
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        for j in range(X.shape[1]):
            e = np.unique(np.quantile(X[:, j], qs))
            self.edges.append(e)
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        out = np.empty(X.shape, np.uint8)
        for j, e in enumerate(self.edges):
            out[:, j] = np.searchsorted(e, X[:, j], side="right")
        return out


@dataclasses.dataclass
class _Node:
    feature: int = -1
    bin_thresh: int = -1
    left: int = -1
    right: int = -1
    value: float = 0.0


class _Tree:
    """One leaf-wise-grown tree over pre-binned features."""

    def __init__(self, cfg: GBDTConfig):
        self.cfg = cfg
        self.nodes: List[_Node] = []

    def _hist(self, B: np.ndarray, idx: np.ndarray, g: np.ndarray,
              h: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(F, n_bins) grad/hess histograms for the rows in idx."""
        n, F = len(idx), B.shape[1]
        nb = self.cfg.n_bins
        flat = (B[idx].astype(np.int32)
                + np.arange(F, dtype=np.int32)[None, :] * nb).ravel()
        gh = np.bincount(flat, weights=np.repeat(g[idx], F), minlength=F * nb)
        hh = np.bincount(flat, weights=np.repeat(h[idx], F), minlength=F * nb)
        return gh.reshape(F, nb), hh.reshape(F, nb)

    def _best_split(self, gh: np.ndarray, hh: np.ndarray,
                    g_sum: float, h_sum: float):
        """Best (feature, bin) split from histograms; returns (gain, f, b)."""
        lam = self.cfg.reg_lambda
        gl = np.cumsum(gh, axis=1)
        hl = np.cumsum(hh, axis=1)
        gr = g_sum - gl
        hr = h_sum - hl
        ok = (hl >= self.cfg.min_child_weight) & (hr >= self.cfg.min_child_weight)
        gain = (gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam)
                - g_sum ** 2 / (h_sum + lam))
        gain = np.where(ok, gain, -np.inf)
        f, b = np.unravel_index(np.argmax(gain), gain.shape)
        return gain[f, b], int(f), int(b)

    def fit(self, B: np.ndarray, g: np.ndarray, h: np.ndarray) -> "_Tree":
        cfg = self.cfg
        root_idx = np.arange(len(g))
        self.nodes = [_Node()]
        gh, hh = self._hist(B, root_idx, g, h)
        # candidate leaves: (gain, node_id, idx, hists, gsum, hsum, split)
        import heapq
        heap = []
        counter = 0

        def push(node_id, idx, gh, hh):
            nonlocal counter
            gs, hs = gh.sum(), hh.sum()
            gain, f, b = self._best_split(gh, hh, gs, hs)
            self.nodes[node_id].value = -gs / (hs + cfg.reg_lambda)
            if np.isfinite(gain) and gain > cfg.min_gain:
                heapq.heappush(heap, (-gain, counter, node_id, idx, gh, hh, f, b))
                counter += 1

        push(0, root_idx, gh, hh)
        n_leaves = 1
        while heap and n_leaves < cfg.max_leaves:
            _, _, node_id, idx, gh, hh, f, b = heapq.heappop(heap)
            mask = B[idx, f] <= b
            li, ri = idx[mask], idx[~mask]
            if len(li) == 0 or len(ri) == 0:
                continue
            ghl, hhl = self._hist(B, li, g, h)
            ghr, hhr = gh - ghl, hh - hhl        # sibling subtraction
            ln, rn = len(self.nodes), len(self.nodes) + 1
            self.nodes.append(_Node())
            self.nodes.append(_Node())
            nd = self.nodes[node_id]
            nd.feature, nd.bin_thresh, nd.left, nd.right = f, b, ln, rn
            push(ln, li, ghl, hhl)
            push(rn, ri, ghr, hhr)
            n_leaves += 1
        return self

    def predict_binned(self, B: np.ndarray) -> np.ndarray:
        out = np.empty(len(B), np.float64)
        node_of = np.zeros(len(B), np.int32)
        active = np.arange(len(B))
        while len(active):
            nid = node_of[active]
            nd_feat = np.array([self.nodes[i].feature for i in nid])
            leaf = nd_feat < 0
            if leaf.any():
                rows = active[leaf]
                out[rows] = [self.nodes[i].value for i in node_of[rows]]
            rest = active[~leaf]
            if not len(rest):
                break
            nid = node_of[rest]
            feats = np.array([self.nodes[i].feature for i in nid])
            ths = np.array([self.nodes[i].bin_thresh for i in nid])
            goleft = B[rest, feats] <= ths
            node_of[rest] = np.where(
                goleft,
                [self.nodes[i].left for i in nid],
                [self.nodes[i].right for i in nid])
            active = rest
        return out


class GBDT:
    """Boosted ensemble; classification via sigmoid(logit)."""

    def __init__(self, cfg: Optional[GBDTConfig] = None, **kw):
        self.cfg = cfg or GBDTConfig(**kw)
        self.trees: List[_Tree] = []
        self.binner: Optional[_Binner] = None
        self.base: float = 0.0

    def _grad_hess(self, y, pred):
        if self.cfg.objective == "l2":
            return pred - y, np.ones_like(y)
        p = 1.0 / (1.0 + np.exp(-pred))
        return p - y, np.maximum(p * (1 - p), 1e-6)

    def _loss(self, y, pred):
        if self.cfg.objective == "l2":
            return float(np.mean((pred - y) ** 2))
        p = np.clip(1.0 / (1.0 + np.exp(-pred)), 1e-12, 1 - 1e-12)
        return float(-np.mean(y * np.log(p) + (1 - y) * np.log(1 - p)))

    def fit(self, X: np.ndarray, y: np.ndarray,
            X_val: Optional[np.ndarray] = None,
            y_val: Optional[np.ndarray] = None) -> "GBDT":
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float64)
        self.binner = _Binner(self.cfg.n_bins).fit(X)
        B = self.binner.transform(X)
        if self.cfg.objective == "l2":
            self.base = float(np.mean(y))
        else:
            p = np.clip(np.mean(y), 1e-6, 1 - 1e-6)
            self.base = float(np.log(p / (1 - p)))
        pred = np.full(len(y), self.base)
        Bv = pv = None
        if X_val is not None and len(X_val):
            Bv = self.binner.transform(np.asarray(X_val, np.float32))
            pv = np.full(len(y_val), self.base)
        best_loss, best_n, since = np.inf, 0, 0
        for _ in range(self.cfg.n_trees):
            g, h = self._grad_hess(y, pred)
            t = _Tree(self.cfg).fit(B, g, h)
            self.trees.append(t)
            pred += self.cfg.learning_rate * t.predict_binned(B)
            if Bv is not None:
                pv += self.cfg.learning_rate * t.predict_binned(Bv)
                vl = self._loss(np.asarray(y_val, np.float64), pv)
                if vl < best_loss - 1e-9:
                    best_loss, best_n, since = vl, len(self.trees), 0
                else:
                    since += 1
                    if since >= self.cfg.early_stopping:
                        break
        if Bv is not None and best_n:
            self.trees = self.trees[:best_n]
        return self

    def raw_predict(self, X: np.ndarray) -> np.ndarray:
        B = self.binner.transform(np.asarray(X, np.float32))
        out = np.full(len(B), self.base)
        for t in self.trees:
            out += self.cfg.learning_rate * t.predict_binned(B)
        return out

    def predict(self, X: np.ndarray) -> np.ndarray:
        raw = self.raw_predict(X)
        if self.cfg.objective == "logloss":
            return 1.0 / (1.0 + np.exp(-raw))
        return raw
