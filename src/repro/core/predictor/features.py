"""Agent-context observation -> feature vectors (§III.A/B).

``x(T)`` concatenates structured features (agent role, workflow position,
invocation index, tool availability, reasoning mode, prompt length) with a
semantic embedding of the input text.

Semantic encoder: the paper uses a sliding-window MiniLM; no pretrained
checkpoints exist offline, so we keep the exact interface and structure
(sliding windows -> per-window embedding -> mean pooling) with a hashed
n-gram projection as the window encoder. The ablation direction
(w/o semantic features degrades R^2 — Table VII) is preserved.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

SEM_DIM = 64
WINDOW = 32
STRIDE = 16


@dataclasses.dataclass
class StageObservation:
    """Compact descriptor captured when a stage is created (§III.A)."""
    app: int                 # application / workflow template id
    role: int                # agent role id
    position: float          # fractional position in the workflow [0,1]
    invocation_idx: int      # how many LLM calls this job has made so far
    tools_available: int     # number of tools the agent may call
    cot: bool                # chain-of-thought / thinking mode enabled
    prompt_len: int          # prompt tokens
    model_id: int            # which model serves this stage
    text: str = ""           # input context (for the semantic encoder)
    src_cluster: int = 0


def _hash_embed(tokens: Sequence[str], dim: int = SEM_DIM) -> np.ndarray:
    """Signed feature hashing of unigrams+bigrams."""
    v = np.zeros(dim, np.float32)
    prev = None
    for t in tokens:
        for gram in ((t,) if prev is None else ((t,), (prev, t))):
            h = hash(gram)
            v[h % dim] += 1.0 if (h >> 31) & 1 else -1.0
        prev = t
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def semantic_embedding(text: str, dim: int = SEM_DIM) -> np.ndarray:
    """Sliding-window encoding + mean pooling (MiniLM stand-in)."""
    toks = text.split()
    if not toks:
        return np.zeros(dim, np.float32)
    wins = []
    for s in range(0, max(1, len(toks) - WINDOW + 1), STRIDE):
        wins.append(_hash_embed(toks[s:s + WINDOW], dim))
        if s + WINDOW >= len(toks):
            break
    return np.mean(wins, axis=0)


N_STRUCT = 8


def structured_features(obs: StageObservation) -> np.ndarray:
    return np.array([
        obs.app, obs.role, obs.position, obs.invocation_idx,
        obs.tools_available, float(obs.cot), np.log1p(obs.prompt_len),
        obs.model_id,
    ], np.float32)


def featurize(obs: StageObservation, semantic: bool = True) -> np.ndarray:
    xs = structured_features(obs)
    if not semantic:
        return xs
    return np.concatenate([xs, semantic_embedding(obs.text)])


def featurize_batch(observations: List[StageObservation],
                    semantic: bool = True) -> np.ndarray:
    return np.stack([featurize(o, semantic) for o in observations])
