"""Canonical cross-cluster network topology shared by BOTH planes.

One RTT matrix drives the trace simulator, the live gateway and every
benchmark, so the controlled policy comparison never diverges on network
assumptions. ``rtt[c1, c2]`` is the round-trip time in seconds between
clusters c1 and c2.
"""
from __future__ import annotations

import numpy as np

# Fig. 4-style regime: two same-region clusters + one remote (seconds)
DEFAULT_RTT = np.array([[0.0005, 0.003, 0.060],
                        [0.003, 0.0005, 0.080],
                        [0.060, 0.080, 0.0005]])

# Table VIII hybrid regime: clusters 0/1 local, cluster 2 far remote
HYBRID_RTT = np.array([[0.0005, 0.002, 0.120],
                       [0.002, 0.0005, 0.140],
                       [0.120, 0.140, 0.0005]])


def validate_rtt(rtt: np.ndarray) -> np.ndarray:
    """Sanity-check and normalize an RTT matrix (square, symmetric, >= 0)."""
    rtt = np.asarray(rtt, float)
    if rtt.ndim != 2 or rtt.shape[0] != rtt.shape[1]:
        raise ValueError(f"RTT matrix must be square, got {rtt.shape}")
    if (rtt < 0).any():
        raise ValueError("RTT entries must be non-negative")
    if not np.allclose(rtt, rtt.T):
        raise ValueError("RTT matrix must be symmetric")
    return rtt
