"""The unified scheduling-policy hierarchy (§IV.A baselines + §III Maestro).

Every policy is written ONCE against the :class:`~repro.core.sched.substrate.
Substrate` protocol and runs unchanged on both planes — the trace-driven
simulator and the live real-engine gateway. All policies share the node
runtime (residency, accounting, profiles), arrivals and SLOs; they differ
ONLY in admission, routing and queue ordering, mirroring the paper's
controlled comparison:

  fcfs          — global FIFO, first feasible node (NOTE: before the API
                  unification the sim plane's fcfs routed least-loaded;
                  that behavior now lives under the explicit name
                  "least-loaded", and fcfs is the pure load-blind baseline
                  on both planes)
  least-loaded  — global FIFO, least-loaded feasible node
  edf           — deadline-first for batch, class priority for interactive
  oracle-srtf   — shortest TRUE remaining time (perfect-knowledge bound)
  maestro       — predicted remaining time (Eq. 7-8) + fitness routing
                  (Eq. 5, Alg. 3) + rho-margin admission + boundary
                  preemption, with Alg. 2 degradation plans entering both
                  feasibility (can_admit) and ranking (C_deg)
  maestro-np    — maestro without boundary preemption (Table II)
  baseline-lb / binpack / maestro-aff — Table VIII routing variants

Policy objects are STATELESS w.r.t. the substrate: the substrate is passed
per call, and all per-run state (controller, prediction cache, preemption
cooldowns) is re-created by ``setup()`` — so one policy instance can be
reused across repeated runs (or across planes) without leaking queue state.

Registering a new policy takes ~10 lines::

    from repro.core.sched.policies import SchedPolicy, register

    class Random(SchedPolicy):
        name = "random"
        def priority(self, sub, stage, now):
            return hash(stage.stage_id) % 1000
    register("random", lambda predictor=None: Random(),
             doc="FIFO-order-free chaos baseline")

Then ``Simulator(jobs, "random")``, ``ClusterGateway(fleet, rtt,
policy="random")`` and both benchmark drivers accept it by name.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.control_loop import MaestroController
from repro.core.sched.fitness import StageRequest
from repro.core.sched.srtf import QueuedStage, SRTFQueue, state_key
from repro.core.sched.substrate import SchedStage, Substrate

_INTERACTIVE_BOOST = 1e9   # interactive class strictly ahead of batch


class SchedPolicy:
    """Unified policy surface: priority / reservation / route / on_finish /
    preemption. Base behavior = non-predictive static reservation and
    least-loaded feasible routing."""

    name = "base"
    requeue_at_boundary = True    # boundary-preemption semantics (§III.D)

    # ------------------------------------------------------------ lifecycle
    def setup(self, sub: Substrate) -> None:
        """Per-run initialization; MUST reset all per-run state."""
        self._guard = SRTFQueue(preempt_gain_s=sub.preempt_gain_s,
                                cooldown_s=sub.preempt_cooldown_s)

    # ------------------------------------------------------------- surface
    def priority(self, sub: Substrate, stage: SchedStage, now: float) -> float:
        """Global-queue order (lower = first)."""
        raise NotImplementedError

    def reservation(self, sub: Substrate, stage: SchedStage) -> float:
        """KV bytes reserved at admission (R_need)."""
        return sub.static_reservation(stage)

    def predicted_len(self, sub: Substrate,
                      stage: SchedStage) -> Optional[float]:
        """L_hat for prediction-guided engine admission (None = none)."""
        return None

    def route(self, sub: Substrate, stage: SchedStage,
              r_need: float) -> Optional[int]:
        """Node id to dispatch to, or None (admission rejection)."""
        best, load = None, float("inf")
        for n in sub.node_ids():
            if sub.can_admit(n, r_need, stage.model):
                l = sub.load(n)
                if l < load:
                    best, load = n, l
        return best

    def should_preempt(self, sub: Substrate, running: SchedStage,
                       running_remaining_s: float, candidate: SchedStage,
                       now: float) -> bool:
        """Boundary preemption decision, guarded by hysteresis + cooldown."""
        if not self.requeue_at_boundary:
            return False
        cand = QueuedStage(
            stage_id=candidate.stage_id, job_id=candidate.job_id,
            interactive=candidate.interactive,
            t_exec=sub.t_exec_est(candidate,
                                  self.predicted_len(sub, candidate)),
            t_future=0.0)
        run = QueuedStage(
            stage_id=running.stage_id, job_id=running.job_id,
            interactive=running.interactive,
            t_exec=running_remaining_s, t_future=0.0)
        return self._guard.should_preempt(run, cand, running_remaining_s, now)

    def on_finish(self, sub: Substrate, stage: SchedStage, actual_kv: float,
                  job_remaining_s: float) -> None:
        """Post-execution calibration hook (substrate clock / bytes)."""


class FCFS(SchedPolicy):
    """Global FIFO + first feasible node; static KV reservation."""
    name = "fcfs"
    requeue_at_boundary = False

    def priority(self, sub, stage, now):
        return float(stage.stage_id)

    def route(self, sub, stage, r_need):
        for n in sub.node_ids():
            if sub.can_admit(n, r_need, stage.model):
                return n
        return None


class LeastLoaded(FCFS):
    """Global FIFO + least-loaded feasible node."""
    name = "least-loaded"

    def route(self, sub, stage, r_need):
        return SchedPolicy.route(self, sub, stage, r_need)


class EDF(SchedPolicy):
    """Earliest absolute deadline for batch, class priority for interactive."""
    name = "edf"
    requeue_at_boundary = False

    def priority(self, sub, stage, now):
        if stage.interactive:
            return -_INTERACTIVE_BOOST + stage.arrival_s
        return stage.arrival_s + stage.deadline_s


class OracleSRTF(SchedPolicy):
    """Shortest TRUE remaining job time — the perfect-knowledge upper bound."""
    name = "oracle-srtf"

    def priority(self, sub, stage, now):
        rem = sub.true_remaining_s(stage)
        return rem - (_INTERACTIVE_BOOST if stage.interactive else 0.0)


class Maestro(SchedPolicy):
    """The full hierarchy: workflow-aware SRTF (Eq. 7-8) + fitness routing
    (Eq. 5-6, Alg. 3) + rho-margin admission + boundary preemption, with
    Alg. 2 degradation cost in the routing score. Whether a policy needs a
    predictor is declared ONLY on its PolicySpec (see ``register`` below)."""
    name = "maestro"

    def __init__(self, predictor, gamma: float = 0.25, preempt: bool = True,
                 weights=None):
        self.predictor = predictor
        self.gamma = gamma
        self.weights = weights          # Optional[FitnessWeights]
        self.requeue_at_boundary = preempt
        self.ctl: Optional[MaestroController] = None
        self._cache: Dict[int, Dict[str, float]] = {}

    # ------------------------------------------------------------ lifecycle
    def setup(self, sub):
        self._guard = SRTFQueue(preempt_gain_s=sub.preempt_gain_s,
                                cooldown_s=sub.preempt_cooldown_s)
        self.ctl = MaestroController(self.predictor, sub.profiles, sub.rtt_s,
                                     weights=self.weights, gamma=self.gamma,
                                     queue=self._guard)
        self._cache = {}
        # batch-precompute per-stage predictions when the substrate knows
        # its stages up-front (same inputs the dispatch gateway would see at
        # stage creation; batching is just speed)
        stages = sub.known_stages()
        if stages and hasattr(self.predictor, "predict"):
            out = self.predictor.predict([s.obs for s in stages])
            for s, L, pt in zip(stages, out["length"], out["p_tool"]):
                self._store(sub, s, float(L), float(pt))

    # ----------------------------------------------------------- prediction
    def _store(self, sub, stage: SchedStage, l_hat: float,
               p_tool: float) -> None:
        prof = sub.profiles[stage.model]
        self._cache[stage.stage_id] = {
            "l_hat": l_hat, "p_tool": p_tool,
            "r_kv_hat": prof.r_kv(stage.prompt_len, l_hat)}

    def _pred(self, sub, stage: SchedStage) -> Dict[str, float]:
        p = self._cache.get(stage.stage_id)
        if p is None:
            out = self.predictor.predict_one(stage.obs)
            self._store(sub, stage, float(out["length"]),
                        float(out["p_tool"]))
            p = self._cache[stage.stage_id]
        return p

    def _state_key(self, stage: SchedStage, p: Dict[str, float]) -> Tuple:
        return state_key(stage.obs.app, stage.obs.role,
                         stage.obs.invocation_idx, p["p_tool"])

    # ------------------------------------------------------------- surface
    def priority(self, sub, stage, now):
        p = self._pred(sub, stage)
        t_rem = (sub.t_exec_est(stage, p["l_hat"])
                 + self.ctl.wf_profiles.future_median(self._state_key(stage,
                                                                      p)))
        # aging prevents starvation of long batch jobs
        wait = max(0.0, now - sub.ready_since(stage.stage_id))
        t_rem -= self.ctl.queue.aging * wait
        return t_rem - (_INTERACTIVE_BOOST if stage.interactive else 0.0)

    def reservation(self, sub, stage):
        return self.ctl.rho.r_need(self._pred(sub, stage)["r_kv_hat"])

    def predicted_len(self, sub, stage):
        return self._pred(sub, stage)["l_hat"]

    def _prefix_digests(self, sub, stage) -> Tuple[str, ...]:
        """Prompt prefix chain for routing; the base hierarchy is
        prefix-blind (see :class:`MaestroPrefix`)."""
        return ()

    def route(self, sub, stage, r_need):
        p = self._pred(sub, stage)
        prof = sub.profiles[stage.model]
        req = StageRequest(
            stage_id=stage.stage_id, model=stage.model, r_need=r_need,
            interactive=stage.interactive, src_cluster=stage.obs.src_cluster,
            t_exec=prof.t_exec(stage.prompt_len, p["l_hat"]),
            prefix_digests=self._prefix_digests(sub, stage))
        # feasibility filter FIRST (Alg. 3 line 3) — eviction-aware, so a
        # node admissible only via degradation stays in and is ranked by its
        # C_deg — then rank by S(N, T)
        nodes = [sub.signal(n) for n in sub.node_ids()
                 if sub.can_admit(n, r_need, stage.model)]
        if not nodes:
            return None
        sel = self.ctl.router.select(
            req, nodes,
            t_act_of=lambda sig, m: sub.t_act(sig.node_id, m),
            c_deg_of=lambda sig, rq: sub.degradation_cost(sig.node_id,
                                                          rq.r_need))
        return None if sel is None else sel[0].node_id

    def on_finish(self, sub, stage, actual_kv, job_remaining_s):
        p = self._pred(sub, stage)
        self.ctl.rho.observe(actual_kv, max(p["r_kv_hat"], 1.0))
        self.ctl.wf_profiles.record(self._state_key(stage, p),
                                    job_remaining_s)


class MaestroNoPreempt(Maestro):
    """Table II ablation: the full hierarchy minus boundary preemption."""
    name = "maestro-np"

    def __init__(self, predictor, gamma: float = 0.25):
        super().__init__(predictor, gamma=gamma, preempt=False)


class BaselineLB(Maestro):
    """Table VIII 'Baseline': load balancing, no prediction-guided packing."""
    name = "baseline-lb"

    def route(self, sub, stage, r_need):
        return SchedPolicy.route(self, sub, stage, r_need)

    def reservation(self, sub, stage):
        return SchedPolicy.reservation(self, sub, stage)


class BinPackOnly(Maestro):
    """Table VIII 'BinPack Only': KV-aware packing, network-blind (gamma=0)."""
    name = "binpack"

    def __init__(self, predictor):
        super().__init__(predictor, gamma=0.0)


class MaestroAff(Maestro):
    """Table VIII 'Maestro-Aff': full fitness scoring (gamma=0.25)."""
    name = "maestro-aff"


class MaestroPrefix(Maestro):
    """Maestro + prefix-affinity routing: successor stages are steered to
    the node whose prefix index already holds their shared prompt prefix
    (system prompt / role template / carried conversation), so the engine
    aliases cached KV pages instead of re-prefilling them."""
    name = "maestro-prefix"

    def __init__(self, predictor, gamma: float = 0.25,
                 w_prefix: float = 0.6):
        from repro.core.sched.fitness import FitnessWeights
        super().__init__(predictor, gamma=gamma,
                         weights=FitnessWeights(w_prefix=w_prefix))

    def _prefix_digests(self, sub, stage):
        return tuple(sub.prefix_digests(stage))


# ---------------------------------------------------------------------------
# Registry: ONE string-dispatch table for both planes and all benchmarks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicySpec:
    name: str
    factory: Callable[..., SchedPolicy]    # factory(predictor=None) -> policy
    needs_predictor: bool = False
    doc: str = ""


POLICIES: Dict[str, PolicySpec] = {}


def register(name: str, factory: Callable[..., SchedPolicy],
             needs_predictor: bool = False, doc: str = "") -> None:
    POLICIES[name] = PolicySpec(name, factory, needs_predictor, doc)


def registered_policies() -> Tuple[str, ...]:
    return tuple(POLICIES)


def make_policy(name: str, predictor=None) -> SchedPolicy:
    """Instantiate a registered policy by name (the single entry point the
    simulator, the gateway, the examples and the benchmarks all use)."""
    spec = POLICIES.get(name)
    if spec is None:
        raise ValueError(f"unknown policy {name!r}; registered: "
                         f"{', '.join(sorted(POLICIES))}")
    if spec.needs_predictor and predictor is None:
        raise ValueError(f"policy {name!r} needs a trained predictor "
                         "(pass predictor=...)")
    return spec.factory(predictor=predictor)


register("fcfs", lambda predictor=None: FCFS(),
         doc="global FIFO, first feasible node")
register("least-loaded", lambda predictor=None: LeastLoaded(),
         doc="global FIFO, least-loaded feasible node")
register("edf", lambda predictor=None: EDF(),
         doc="deadline-first batch, class-priority interactive")
register("oracle-srtf", lambda predictor=None: OracleSRTF(),
         doc="true shortest-remaining-time (perfect-knowledge bound)")
register("maestro", lambda predictor=None: Maestro(predictor),
         needs_predictor=True, doc="full hierarchy (Eq. 5-8, Alg. 2-3)")
register("maestro-np", lambda predictor=None: MaestroNoPreempt(predictor),
         needs_predictor=True, doc="maestro without boundary preemption")
register("baseline-lb", lambda predictor=None: BaselineLB(predictor),
         needs_predictor=True, doc="Table VIII load-balancing baseline")
register("binpack", lambda predictor=None: BinPackOnly(predictor),
         needs_predictor=True, doc="Table VIII network-blind packing")
register("maestro-aff", lambda predictor=None: MaestroAff(predictor),
         needs_predictor=True, doc="Table VIII full fitness scoring")
register("maestro-prefix", lambda predictor=None: MaestroPrefix(predictor),
         needs_predictor=True,
         doc="maestro + prefix-affinity routing over cached KV prefixes")
