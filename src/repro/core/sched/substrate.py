"""Substrate-agnostic scheduling interface: the seam between policies and
the serving planes.

The paper's central claim is a CONTROLLED comparison — fcfs / edf /
oracle-srtf / maestro(-np) and the Table VIII routing variants differ only
in admission, routing and queue order. This module makes that structural:
a policy sees stages only through the :class:`SchedStage` view and a plane
only through the :class:`Substrate` protocol, so the exact same policy
object schedules the trace-driven simulator (``repro.sim.simulator``) and
the live real-engine gateway (``repro.serving.gateway``).

Substrate time is opaque to policies: the simulator's clock runs in model
seconds, the gateway's in whatever its pluggable clock provides (virtual
tick seconds by default, real elapsed seconds under the wall clock — see
``repro.serving.clock``). All durations a policy touches (``t_exec_est``,
``true_remaining_s``, ``preempt_gain_s``, the ``job_remaining_s`` it
records on finish) are expressed in SECONDS on the substrate's own clock —
never in ticks — so relative ordering (the only thing scheduling decisions
depend on) is preserved across planes and across clocks, and hysteresis
thresholds like ``preempt_gain_s`` are clock-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.predictor.cost_model import ModelProfile
from repro.core.predictor.features import StageObservation
from repro.core.sched.fitness import NodeSignal


@dataclasses.dataclass(frozen=True)
class SchedStage:
    """What a policy is allowed to know about a stage: identity, model,
    prompt size, SLO class and the job's arrival/deadline. Ground truth
    (true output lengths) is NOT here — oracle knowledge goes through
    ``Substrate.true_remaining_s`` so its use is explicit."""
    stage_id: int
    job_id: int
    model: str                 # serving-model name (a key of sub.profiles)
    interactive: bool          # SLO class
    prompt_len: int            # trace-scale prompt length (cost-model input)
    arrival_s: float           # job arrival on the substrate clock
    deadline_s: float          # job SLO deadline, relative to arrival
    obs: StageObservation      # full observation (predictor input)


@runtime_checkable
class Substrate(Protocol):
    """What a serving plane exposes to policies. Implemented by
    ``repro.sim.simulator.Simulator`` and
    ``repro.serving.gateway.ClusterGateway``."""

    profiles: Dict[str, ModelProfile]
    rtt_s: np.ndarray                 # canonical cluster RTT matrix
    preempt_gain_s: float             # boundary-preemption hysteresis
    preempt_cooldown_s: float         # per-job preemption cooldown

    # ------------------------------------------------------------- fleet
    def node_ids(self) -> Sequence[int]:
        """All node ids, in stable order."""

    def signal(self, node_id: int) -> NodeSignal:
        """Current NodeSignal (headroom / queue delay / warm set) of a node."""

    def load(self, node_id: int) -> int:
        """In-flight stage count on a node (least-loaded routing input)."""

    def can_admit(self, node_id: int, r_need: float,
                  model: Optional[str] = None) -> bool:
        """Eviction-aware admission feasibility: slot available AND r_need
        bytes admissible, counting what Alg. 2 degradation could free."""

    def t_act(self, node_id: int, model: str) -> float:
        """Estimated activation latency T_act (Eq. 6), no side effects."""

    def degradation_cost(self, node_id: int, r_need: float) -> Optional[float]:
        """C_deg of admitting r_need via an Algorithm 2 plan (0.0 when no
        degradation is needed, None when impossible)."""

    # ------------------------------------------------------------- stages
    def known_stages(self) -> List[SchedStage]:
        """Stages known up-front (trace replay); [] for online arrivals.
        Lets predictive policies batch-precompute at setup time."""

    def static_reservation(self, stage: SchedStage) -> float:
        """Non-predictive KV reservation (baseline policies' R_need)."""

    def t_exec_est(self, stage: SchedStage, l_hat: Optional[float]) -> float:
        """Estimated stage execution time on the substrate clock for a
        predicted output length; l_hat=None means the substrate's nominal
        decode budget (non-predictive estimate)."""

    def true_remaining_s(self, stage: SchedStage) -> float:
        """TRUE remaining execution time of the stage's job including this
        stage (oracle knowledge — only Oracle-SRTF may call this)."""

    def ready_since(self, stage_id: int) -> float:
        """Substrate time the stage entered the global queue (aging input);
        +inf when unknown (treated as zero wait)."""

    def prefix_digests(self, stage: SchedStage) -> Sequence[str]:
        """Chained prefix-page digests of the stage's prompt, for
        prefix-affinity routing; () on planes without token-level prompts
        (the trace simulator) or when the prefix cache is disabled."""
