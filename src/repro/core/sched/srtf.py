"""Workflow-aware preemptive Shortest-Remaining-Time-First queueing
(§III.D, Eq. 7-8).

Global queue orders jobs by estimated remaining workflow time:
    T_rem(J,k) = T_exec(T_k) + T_future(J,k)                       (Eq. 7)
    T_future(J,k) ~ median of recent next-stage-onward times,
                    conditioned on state(J,k)                      (Eq. 8)
state(J,k) = (workflow template, agent role, invocation-index bucket,
discretized tool-intent score).

Preemption is boundary-only (between LLM invocations), guarded by hysteresis
(min predicted gain + per-job cooldown); aging raises long-waiting background
jobs to prevent starvation. Interactive stages always outrank batch ones.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


def state_key(app: int, role: int, invocation_idx: int,
              p_tool: float) -> Tuple[int, int, int, int]:
    return (app, role, min(invocation_idx, 8),
            int(min(max(p_tool, 0.0), 0.999) * 4))  # 4 intent buckets


class WorkflowProfileStore:
    """Rolling execution profiles per workflow template (Eq. 8)."""

    def __init__(self, window: int = 128, default_future: float = 10.0):
        self.hist: Dict[Tuple, Deque[float]] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self.default = default_future

    def record(self, key: Tuple, future_seconds: float) -> None:
        self.hist[key].append(float(future_seconds))

    def future_median(self, key: Tuple) -> float:
        h = self.hist.get(key)
        if not h:
            # back off to coarser keys (drop intent bucket, then invocation)
            h = self.hist.get(key[:3] + (0,))
        if not h:
            return self.default
        return float(np.median(np.asarray(h)))


@dataclasses.dataclass(order=True)
class _QEntry:
    priority: float
    seq: int
    stage: object = dataclasses.field(compare=False)


@dataclasses.dataclass
class QueuedStage:
    stage_id: int
    job_id: int
    interactive: bool
    t_exec: float              # Eq. 2 estimate for the current stage
    t_future: float            # Eq. 8
    enqueue_time: float = 0.0

    @property
    def t_rem(self) -> float:
        return self.t_exec + self.t_future


class SRTFQueue:
    """Two-level queueing's GLOBAL queue: remaining-time order with class
    separation, aging, and boundary-preemption decisions."""

    def __init__(self, aging_factor: float = 0.02,
                 preempt_gain_s: float = 1.0, cooldown_s: float = 5.0):
        self.aging = aging_factor
        self.preempt_gain = preempt_gain_s
        self.cooldown = cooldown_s
        self._heap: List[_QEntry] = []
        self._seq = 0
        self._removed: set = set()
        self.last_preempt: Dict[int, float] = {}   # job -> time

    def _priority(self, s: QueuedStage, now: float) -> float:
        aged = s.t_rem - self.aging * max(0.0, now - s.enqueue_time)
        # interactive class strictly ahead of batch (mixed SLOs)
        return aged - (1e6 if s.interactive else 0.0)

    def push(self, s: QueuedStage, now: float) -> None:
        s.enqueue_time = s.enqueue_time or now
        self._seq += 1
        heapq.heappush(self._heap, _QEntry(self._priority(s, now),
                                           self._seq, s))

    def pop(self, now: float) -> Optional[QueuedStage]:
        while self._heap:
            e = heapq.heappop(self._heap)
            if id(e.stage) in self._removed:
                self._removed.discard(id(e.stage))
                continue
            return e.stage
        return None

    def peek(self) -> Optional[QueuedStage]:
        while self._heap:
            e = self._heap[0]
            if id(e.stage) in self._removed:
                heapq.heappop(self._heap)
                self._removed.discard(id(e.stage))
                continue
            return e.stage
        return None

    def refresh(self, now: float) -> None:
        """Recompute aged priorities (heap entries are stale otherwise)."""
        live = []
        while self._heap:
            e = heapq.heappop(self._heap)
            if id(e.stage) in self._removed:
                self._removed.discard(id(e.stage))
                continue
            live.append(e.stage)
        for s in live:
            self._seq += 1
            heapq.heappush(self._heap, _QEntry(self._priority(s, now),
                                               self._seq, s))

    def remove(self, s: QueuedStage) -> None:
        self._removed.add(id(s))

    def __len__(self) -> int:
        return len(self._heap) - len(self._removed)

    # --------------------------------------------------------- preemption
    def should_preempt(self, running: QueuedStage, candidate: QueuedStage,
                       running_remaining_s: float, now: float) -> bool:
        """Boundary preemption with hysteresis: only when the predicted
        latency gain exceeds the threshold and the job's cooldown expired.
        Never preempt interactive work for batch work."""
        if running.interactive and not candidate.interactive:
            return False
        gain = running_remaining_s - candidate.t_exec
        if candidate.interactive and not running.interactive:
            gain = running_remaining_s  # class override still needs cooldown
        if gain < self.preempt_gain:
            return False
        last = self.last_preempt.get(running.job_id, -1e18)
        if now - last < self.cooldown:
            return False
        self.last_preempt[running.job_id] = now
        return True
