"""Feasibility-aware cross-cluster fitness routing (§III.D, Eq. 5-6, Alg. 3).

    S(N, T) = A(N, T) - lambda * T_ready(N, T) - mu * C_deg(N, T)

A(N,T) combines network proximity (decreasing transform of RTT) with KV-fit
best-fit packing over the runtime-reported headroom. All metrics pass through
robust 5/95-percentile min-max normalization over a recent window so outlier
RTT or activation estimates cannot dominate. T_ready = T_q + T_act (Eq. 6).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


class RobustNormalizer:
    """Rolling per-metric 5/95-percentile min-max with clipping."""

    def __init__(self, window: int = 256):
        self.hist: Dict[str, Deque[float]] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))

    def observe(self, metric: str, value: float) -> None:
        self.hist[metric].append(float(value))

    def norm(self, metric: str, value: float) -> float:
        h = self.hist[metric]
        if len(h) < 4:
            return 0.0 if value <= 0 else 0.5
        a = np.asarray(h)
        lo, hi = np.percentile(a, 5), np.percentile(a, 95)
        if hi - lo < 1e-12:
            return 0.5
        return float(np.clip((value - lo) / (hi - lo), 0.0, 1.0))


@dataclasses.dataclass
class NodeSignal:
    """What each node runtime periodically reports to the global scheduler."""
    node_id: int
    cluster_id: int
    headroom: float                    # R_kv_head(N)
    queue_delay_s: float               # EWMA'd T_q
    warm_models: Dict[str, float]      # model -> T_act seconds (Eq. 6)
    supports_vmm: bool = True          # elastic-KV capability signal
    total_hbm: float = 16e9
    # most-recent prefix-page digests held by the node's prefix index
    # (compact content summary; rides the existing signal snapshot)
    prefix_digests: Tuple[str, ...] = ()


@dataclasses.dataclass
class StageRequest:
    stage_id: int
    model: str
    r_need: float                      # (1+rho) * R_kv_hat
    interactive: bool
    src_cluster: int
    t_exec: float                      # Eq. 2 (node-invariant)
    high_concurrency: bool = False
    # chained page digests of the stage's prompt (empty: no prefix routing)
    prefix_digests: Tuple[str, ...] = ()


@dataclasses.dataclass
class FitnessWeights:
    w_net: float = 0.5
    w_fit: float = 0.5
    lam: float = 1.0
    mu: float = 1.0
    # interactive stages weight the network term up (§III.D)
    w_net_interactive: float = 0.75
    # prefix-affinity term: reward nodes already holding the stage's prompt
    # prefix (0 keeps scoring identical to the base router)
    w_prefix: float = 0.0


class FitnessRouter:
    """Algorithm 3."""

    def __init__(self, rtt_s: np.ndarray,
                 weights: Optional[FitnessWeights] = None,
                 gamma: float = 0.25):
        """rtt_s[c1, c2] = RTT between clusters (seconds).
        gamma scales the network component (0 => BinPack-only baseline)."""
        self.rtt = rtt_s
        self.w = weights or FitnessWeights()
        self.gamma = gamma
        self.normalizer = RobustNormalizer()

    def affinity(self, rtt: float, headroom: float, r_need: float,
                 interactive: bool) -> float:
        w_net = self.w.w_net_interactive if interactive else self.w.w_net
        w_net *= self.gamma / 0.25 if self.gamma else 0.0
        net = 1.0 - self.normalizer.norm("rtt", rtt)
        # best-fit packing: prefer nodes whose headroom is close to r_need
        # (from above) among feasible candidates
        slack = (headroom - r_need) / max(headroom, 1e-9)
        fit = 1.0 - float(np.clip(slack, 0.0, 1.0))
        return w_net * net + self.w.w_fit * fit

    def score(self, sig: NodeSignal, req: StageRequest,
              t_act: float, c_deg: float) -> float:
        rtt = float(self.rtt[req.src_cluster, sig.cluster_id])
        self.normalizer.observe("rtt", rtt)
        t_ready = sig.queue_delay_s + t_act
        self.normalizer.observe("t_ready", t_ready)
        self.normalizer.observe("c_deg", c_deg)
        a = self.affinity(rtt, sig.headroom, req.r_need, req.interactive)
        a += self.w.w_prefix * self.prefix_affinity(sig, req)
        return (a - self.w.lam * self.normalizer.norm("t_ready", t_ready)
                - self.w.mu * self.normalizer.norm("c_deg", c_deg))

    def prefix_affinity(self, sig: NodeSignal, req: StageRequest) -> float:
        """Fraction of the stage's prefix chain the node already holds.

        Digests chain (page i commits to pages 0..i), so the walk stops at
        the first digest the node does not advertise — matching exactly the
        pages the engine could alias on arrival."""
        if not self.w.w_prefix or not req.prefix_digests:
            return 0.0
        held = set(sig.prefix_digests)
        n = 0
        for d in req.prefix_digests:
            if d not in held:
                break
            n += 1
        return n / len(req.prefix_digests)

    def select(self, req: StageRequest, nodes: Sequence[NodeSignal],
               t_act_of, c_deg_of) -> Optional[Tuple[NodeSignal, float]]:
        """Filter by feasibility, rank by S(N,T). ``t_act_of(node, model)`` and
        ``c_deg_of(node, req)`` are runtime estimate callbacks."""
        best, best_s = None, -np.inf
        for sig in nodes:
            c_deg = 0.0
            if sig.headroom < req.r_need:
                # infeasible without degradation: runtime reports plan cost,
                # or None when impossible -> filtered out
                c_deg = c_deg_of(sig, req)
                if c_deg is None:
                    continue
            if req.high_concurrency and not sig.supports_vmm:
                continue  # hard capability constraint
            s = self.score(sig, req, t_act_of(sig, req.model), c_deg)
            if s > best_s:
                best, best_s = sig, s
        if best is None:
            return None
        return best, best_s
