"""Adaptive KV safety margin rho (§III.D).

R_need(T) = (1 + rho) * R_kv_hat(T), where rho tracks a high quantile of the
relative underestimation e = max(0, R_kv / R_kv_hat - 1) over a sliding
window, EWMA-smoothed. In practice rho lands in [0.1, 0.3].
"""
from __future__ import annotations

import collections
from typing import Deque

import numpy as np


class RhoEstimator:
    def __init__(self, quantile: float = 0.9, window: int = 512,
                 ewma: float = 0.2, rho_min: float = 0.05,
                 rho_max: float = 1.0, rho_init: float = 0.2):
        self.q = quantile
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.ewma = ewma
        self.lo, self.hi = rho_min, rho_max
        self.rho = rho_init

    def observe(self, actual_kv: float, predicted_kv: float) -> None:
        e = max(0.0, actual_kv / max(predicted_kv, 1e-9) - 1.0)
        self.window.append(e)
        if len(self.window) >= 8:
            q = float(np.quantile(np.asarray(self.window), self.q))
            self.rho = (1 - self.ewma) * self.rho + self.ewma * q
            self.rho = min(max(self.rho, self.lo), self.hi)

    def r_need(self, r_kv_hat: float) -> float:
        return (1.0 + self.rho) * r_kv_hat
