"""Maestro's contribution: agent-aware cost prediction, node-level
multi-model runtime, and workload-aware cross-cluster scheduling."""
