"""Train/serve step builders: value_and_grad + microbatch accumulation +
AdamW, and the inference steps (prefill / decode) — all as pure functions
ready for ``jax.jit`` with explicit in/out shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, input_specs
from repro.models.transformer import Model
from repro.training.optimizer import (OptConfig, abstract_opt_state,
                                      adamw_update, opt_pspecs)


def _split_batch(batch: Dict[str, jax.Array]):
    toks = batch["tokens"]
    labels = batch.get("labels")
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    return toks, labels, extras


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        toks, labels, extras = _split_batch(batch)
        return model.loss(params, toks, labels, extras)
    return loss_fn


def make_train_step(model: Model, opt_cfg: OptConfig, n_micro: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # microbatch accumulation: scan over [n_micro, mb, ...] slices
            def reshape(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])
            mbatch = jax.tree.map(reshape, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                tot_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     tot_g, g)
                return (tot_l + l, tot_g), None

            (loss, grads), _ = lax.scan(acc, (jnp.zeros(()), zero), mbatch)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        toks, _, extras = _split_batch(batch)
        return model.prefill(params, toks, extras)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, positions):
        return model.decode_step(params, cache, tokens, positions)
    return decode_step


# ---------------------------------------------------------------------------
# Abstract in/out for AOT lowering (dry-run)
# ---------------------------------------------------------------------------

def batch_pspecs(model: Model, shape_name: str):
    """PartitionSpec per batch input: batch dim over dp, rest replicated."""
    ctx = model.ctx
    specs = {}
    for k, s in input_specs(model.cfg, shape_name).items():
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        specs[k] = ctx.spec(*axes, dims=s.shape)
    return specs


def lower_cell(model: Model, shape_name: str, opt_cfg: Optional[OptConfig] = None,
               n_micro: int = 1):
    """AOT-lower the step for one (arch, shape) cell on the model's mesh.

    Returns the jax ``Lowered`` object (call .compile() on it).
    """
    cfg = model.cfg
    mesh = model.ctx.mesh
    kind = SHAPES[shape_name]["kind"]
    named = lambda spec_tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))

    p_abs = model.abstract_params()
    p_sh = named(model.param_pspecs())
    b_abs = input_specs(cfg, shape_name)
    b_sh = named(batch_pspecs(model, shape_name))

    if kind == "train":
        opt_cfg = opt_cfg or OptConfig()
        step = make_train_step(model, opt_cfg, n_micro)
        o_abs = abstract_opt_state(p_abs, opt_cfg.compression)
        o_sh = named(opt_pspecs(model.param_pspecs(), opt_cfg.compression))
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
        return fn.lower(p_abs, o_abs, b_abs)
    if kind == "prefill":
        step = make_prefill_step(model)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh))
        return fn.lower(p_abs, b_abs)
    # decode: one new token against a KV cache of length seq_len
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    structs, cspecs = model.cache_specs(B, S)
    c_sh = named(cspecs)
    step = make_decode_step(model)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = named(model.ctx.spec("batch", None, dims=(B, 1)))
    pos_sh = named(model.ctx.spec("batch", dims=(B,)))
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                 donate_argnums=(1,))
    return fn.lower(p_abs, structs, tok_abs, pos_abs)
