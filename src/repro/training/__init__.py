from repro.training.optimizer import (OptConfig, abstract_opt_state,
                                      adamw_init, adamw_update, opt_pspecs)
from repro.training.train_step import (batch_pspecs, lower_cell,
                                       make_decode_step, make_loss_fn,
                                       make_prefill_step, make_train_step)

__all__ = [
    "OptConfig", "abstract_opt_state", "adamw_init", "adamw_update",
    "opt_pspecs", "batch_pspecs", "lower_cell", "make_decode_step",
    "make_loss_fn", "make_prefill_step", "make_train_step",
]
