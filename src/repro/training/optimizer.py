"""AdamW with fp32 moments over (possibly bf16) sharded parameters, plus an
optional gradient-compression transform (bf16/int8 with error feedback) that
can be applied before the DP all-reduce to cut collective bytes.

Optimizer state is sharded identically to the parameters (the m/v trees reuse
the parameter PartitionSpecs), so ZeRO-style memory scaling falls out of the
parameter sharding rules for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # gradient compression: none | bf16 | int8 (error feedback kept in state)
    compression: str = "none"
    warmup_steps: int = 100


def adamw_init(params, compression: str = "none"):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if compression != "none":
        state["ef"] = jax.tree.map(zeros32, params)  # error-feedback residual
    return state


def abstract_opt_state(abstract_params, compression: str = "none"):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if compression != "none":
        state["ef"] = jax.tree.map(f32, abstract_params)
    return state


def opt_pspecs(param_pspecs, compression: str = "none"):
    from jax.sharding import PartitionSpec as P
    state = {"m": param_pspecs, "v": param_pspecs, "step": P()}
    if compression != "none":
        state["ef"] = param_pspecs
    return state


def compress_grads(grads, state, cfg: OptConfig):
    """Lossy-compress gradients with error feedback. Models the wire format the
    DP all-reduce would carry; returns decompressed f32 grads + new residual."""
    if cfg.compression == "none":
        return grads, state

    def comp(g, ef):
        g = g.astype(jnp.float32) + ef
        if cfg.compression == "bf16":
            q = g.astype(jnp.bfloat16).astype(jnp.float32)
        elif cfg.compression == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = (jnp.round(g / scale).astype(jnp.int8).astype(jnp.float32)
                 * scale)
        else:
            raise ValueError(cfg.compression)
        return q, g - q

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_ef = tdef.flatten_up_to(state["ef"])
    out = [comp(g, e) for g, e in zip(flat_g, flat_ef)]
    new_g = tdef.unflatten([o[0] for o in out])
    new_ef = tdef.unflatten([o[1] for o in out])
    return new_g, {**state, "ef": new_ef}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, state, params, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads, state = compress_grads(grads, state, cfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    step = state["step"] + 1
    warm = jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    lr = cfg.lr * warm
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {**state,
                 "m": tdef.unflatten([o[1] for o in out]),
                 "v": tdef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
