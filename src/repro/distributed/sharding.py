"""Logical-axis sharding rules and the ShardingCtx threaded through models.

Meshes (see repro.launch.mesh):
    single-pod : (data=16, model=16)            axes ("data", "model")
    multi-pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")

Logical axes:
    "fsdp"  — ZeRO-3 parameter sharding over ("pod","data")
    "tp"    — tensor parallel over "model"
    "exp"   — expert parallel over "model"
    "batch" — activation batch over ("pod","data")
    "sp"    — activation sequence over "model" (Megatron-SP residual stream)
    "kv_sp" — decode KV cache sequence over "model" (flash-decode combine)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_rules(mesh: Optional[Mesh]) -> Dict[Optional[str], Any]:
    """Map logical axes -> mesh axes for the given mesh (None => no sharding)."""
    if mesh is None:
        return {}
    names = mesh.axis_names
    if "pod" in names:
        dp: Any = ("pod", "data")
    else:
        dp = "data"
    return {
        "fsdp": dp,
        "batch": dp,
        "tp": "model",
        "exp": "model",
        "sp": "model",
        "kv_sp": "model",
        "stack": None,
        None: None,
    }


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclasses.dataclass
class ShardingCtx:
    """Applies logical-axis sharding constraints; no-op when mesh is None.

    ``cs(x, *axes)`` constrains array ``x`` so that dim i is sharded along the
    mesh axes that logical axis ``axes[i]`` maps to — skipping axes whose mesh
    extent does not divide the dim (e.g. batch=1 long-context decode).

    ``mode`` selects the distribution strategy for activations (parameters
    are 2D-sharded identically in both):
      "tp_sp"   — paper-era Megatron tensor-parallel + sequence-parallel:
                  heads/d_ff sharded over "model", activations gathered to
                  full-seq around attention/FFN (the BASELINE).
      "fsdp_cp" — ZeRO-3 + sequence-context-parallelism: activations stay
                  (batch x seq)-sharded everywhere, weights are all-gathered
                  per layer (overlappable), attention flash-scans over
                  gathered K/V (GQA keeps them small). The beyond-paper
                  optimized mode (see EXPERIMENTS.md §Perf).
    """
    mesh: Optional[Mesh] = None
    mode: str = "tp_sp"

    def __post_init__(self):
        self.rules = mesh_rules(self.mesh)

    @property
    def enabled(self) -> bool:
        return self.mesh is not None and self.mesh.size > 1

    def axis_size(self, logical: Optional[str]) -> int:
        if not self.enabled or logical is None:
            return 1
        mesh_axes = self.rules.get(logical)
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        size = 1
        for a in mesh_axes:
            size *= self.mesh.shape[a]
        return size

    def spec(self, *axes: Optional[str], dims: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical axes; if dims given, drop non-dividing axes."""
        entries = []
        for i, a in enumerate(axes):
            mesh_axes = self.rules.get(a) if self.enabled else None
            if mesh_axes is not None and dims is not None:
                if not _divides(dims[i], self.axis_size(a)):
                    mesh_axes = None
            entries.append(mesh_axes)
        return P(*entries)

    def cs(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        if not self.enabled:
            return x
        assert len(axes) == x.ndim, (axes, x.shape)
        spec = self.spec(*axes, dims=x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def named(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    # -- shard_map support (flash-decode island) ----------------------------
    @property
    def tp_axis(self) -> Optional[str]:
        return "model" if (self.enabled and "model" in self.mesh.axis_names) else None

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        if not self.enabled:
            return ()
        return ("pod", "data") if "pod" in self.mesh.axis_names else ("data",)


def param_shardings(mesh: Optional[Mesh], defs):
    """PartitionSpec tree (or NamedSharding tree) for a Leaf-def tree."""
    from repro.models.common import pspec_tree
    rules = mesh_rules(mesh)
    return pspec_tree(defs, rules)


def to_named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))
