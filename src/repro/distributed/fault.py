"""Fault tolerance + straggler mitigation for 1000+-node operation.

``StragglerDetector`` — EWMA step-time tracking with z-score outlier calls;
the cluster manager re-dispatches work from flagged nodes (the simulator and
the serving engine both consult it).

``ElasticController`` — plans recovery after node failures: chooses the
largest feasible mesh from the survivors, and the restore path re-shards the
latest checkpoint onto it (repro.checkpoint.restore with new shardings).
This is checkpoint-restart elasticity: no in-flight state migration, which
matches how large TPU fleets actually recover.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class StragglerDetector:
    def __init__(self, alpha: float = 0.2, z_thresh: float = 3.0,
                 min_obs: int = 8):
        self.alpha = alpha
        self.z = z_thresh
        self.min_obs = min_obs
        self.mean: Dict[int, float] = {}
        self.var: Dict[int, float] = {}
        self.count: Dict[int, int] = {}

    def observe(self, node: int, step_s: float) -> None:
        m = self.mean.get(node, step_s)
        v = self.var.get(node, 0.0)
        d = step_s - m
        self.mean[node] = m + self.alpha * d
        self.var[node] = (1 - self.alpha) * (v + self.alpha * d * d)
        self.count[node] = self.count.get(node, 0) + 1

    def is_straggler(self, node: int, step_s: float) -> bool:
        """Is this step-time an outlier vs the FLEET distribution?"""
        if len(self.mean) < 2 or self.count.get(node, 0) < self.min_obs:
            return False
        fleet = np.array([self.mean[n] for n in self.mean if n != node])
        mu, sd = float(fleet.mean()), float(fleet.std() + 1e-9)
        return (step_s - mu) / sd > self.z

    def forget(self, node: int) -> None:
        """Drop a node's history when it leaves the fleet (death or
        retirement) so its stale mean stops skewing the fleet distribution
        every later node is judged against."""
        self.mean.pop(node, None)
        self.var.pop(node, None)
        self.count.pop(node, None)

    def stragglers(self) -> List[int]:
        if len(self.mean) < 3:
            return []
        vals = np.array(list(self.mean.values()))
        mu, sd = float(vals.mean()), float(vals.std() + 1e-9)
        return [n for n, m in self.mean.items()
                if (m - mu) / sd > self.z
                and self.count.get(n, 0) >= self.min_obs]


@dataclasses.dataclass
class RecoveryPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_nodes: Tuple[int, ...]
    restore_step: Optional[int]


class ElasticController:
    """Pick the largest viable (data, model) mesh from surviving chips and
    plan a checkpoint-restart onto it."""

    def __init__(self, model_axis: int = 16, min_data: int = 1):
        self.model_axis = model_axis
        self.min_data = min_data

    def plan(self, total_chips: int, failed: Sequence[int],
             ckpt_step: Optional[int]) -> Optional[RecoveryPlan]:
        alive = total_chips - len(failed)
        data = alive // self.model_axis
        if data < self.min_data:
            return None
        # power-of-two data axis keeps batch divisibility
        data = 1 << (data.bit_length() - 1)
        return RecoveryPlan(mesh_shape=(data, self.model_axis),
                            axis_names=("data", "model"),
                            dropped_nodes=tuple(failed),
                            restore_step=ckpt_step)
