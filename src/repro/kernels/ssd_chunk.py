"""Pallas TPU Mamba2 SSD chunk kernel.

Grid (batch, head_blocks, chunks); the chunk dimension is innermost and
sequential on TPU, so the recurrent state [bh, N, P] is carried in VMEM
scratch across chunk steps — the whole intra-chunk quadratic term (the
C B^T (.) L masked matmul) stays in VMEM and never touches HBM, which is
exactly the memory win over the jnp reference (which materializes the
[B, Q, Q, H] decay tensor per chunk).

Chunk = 256 and head_dim/d_state multiples of 64/128 keep the MXU fed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr,
                *, nc: int, Q: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # [Q, bh, P]
    dt = dt_ref[0].astype(jnp.float32)      # [Q, bh]
    A = a_ref[...]                          # [bh]
    Bm = b_ref[0].astype(jnp.float32)       # [Q, bh, N]
    Cm = c_ref[0].astype(jnp.float32)       # [Q, bh, N]

    dA = dt * A[None, :]                    # [Q, bh]
    dA_cs = jnp.cumsum(dA, axis=0)          # inclusive
    xdt = x * dt[..., None]                 # [Q, bh, P]

    # intra-chunk: scores[q,k,h] = C_q . B_k, masked-decayed
    scores = jax.lax.dot_general(
        Cm, Bm, (((2,), (2,)), ((1,), (1,))),
        preferred_element_type=jnp.float32)            # [bh, Q, Q]
    L = jnp.exp(dA_cs.T[:, :, None] - dA_cs.T[:, None, :])   # [bh, Q, Q]
    iq = jax.lax.broadcasted_iota(jnp.int32, L.shape, 1)
    ik = jax.lax.broadcasted_iota(jnp.int32, L.shape, 2)
    M = jnp.where(iq >= ik, scores * L, 0.0)
    y = jax.lax.dot_general(
        M, xdt, (((2,), (0,)), ((0,), (1,))))          # [bh, Q, P]

    # inter-chunk: y += (C_q * exp(dA_cs)) . state_prev
    c_dec = Cm * jnp.exp(dA_cs)[..., None]             # [Q, bh, N]
    y = y + jax.lax.dot_general(
        c_dec, state_scr[...], (((2,), (1,)), ((1,), (0,))))  # [bh, Q, P]

    # state update: state = exp(sum dA) * state + (B * decay_to_end)^T xdt
    decay_end = jnp.exp(dA_cs[-1][None, :] - dA_cs)    # [Q, bh]
    b_dec = Bm * decay_end[..., None]                  # [Q, bh, N]
    new_contrib = jax.lax.dot_general(
        b_dec, xdt, (((0,), (0,)), ((1,), (1,))))      # [bh, N, P]
    state_scr[...] = (state_scr[...]
                      * jnp.exp(dA_cs[-1]).T[:, None, None]
                      + new_contrib)
    y_ref[0] = y.transpose(1, 0, 2).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_heads",
                                             "interpret"))
def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
              Cm: jax.Array, chunk: int = 256, block_heads: int = 8,
              interpret: bool = False) -> jax.Array:
    """x [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (negative);
    Bm/Cm [B,S,H,N] (head-broadcast). Returns y [B,S,H,P]."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    bh = min(block_heads, H)
    while H % bh:
        bh -= 1
    grid = (B, H // bh, nc)
    kernel = functools.partial(_ssd_kernel, nc=nc, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, bh, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, bh), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((bh,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, bh, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, bh, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, bh, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((bh, N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
