"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, causal: bool = True):
    """q [B,Sq,H,hd]; k/v [B,Sk,Hkv,hd]."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens,
                        k_new=None, v_new=None):
    """q [B,H,hd]; pages [n_pages, page, Hkv, hd]; block_table [B,slots].

    ``seq_lens`` is clamped to >= 1 (matching the Pallas kernel's contract):
    a zero-length row would softmax over an all-masked score vector and emit
    NaN — serving points idle decode slots at a null page instead.

    ``k_new``/``v_new`` [B,Hkv,hd] (optional): the current token's K/V,
    spliced into each sequence's gathered view at position ``seq_len - 1``
    WITHOUT requiring the caller to scatter it into the page arrays first.
    This is the in-horizon visibility hook of the multi-token decode loop:
    the freshly projected K/V of iteration ``h`` is read by iteration ``h``'s
    own attention inline, and the page-store scatter (still needed so
    iterations ``> h`` see it) drops off the attention's critical path. The
    spliced tensor is elementwise identical to scatter-then-gather for every
    live lane (private row, unique offset), so outputs are bitwise equal to
    the pre-scatter path."""
    B, H, hd = q.shape
    n_pages, page, Hkv, _ = k_pages.shape
    slots = block_table.shape[1]
    seq_lens = jnp.maximum(seq_lens, 1)
    # gather each sequence's pages into a contiguous [B, slots*page, Hkv, hd]
    k = k_pages[block_table].reshape(B, slots * page, Hkv, hd)
    v = v_pages[block_table].reshape(B, slots * page, Hkv, hd)
    if k_new is not None:
        w = (jnp.arange(slots * page)[None, :]
             == (seq_lens - 1)[:, None])[..., None, None]
        k = jnp.where(w, k_new[:, None].astype(k.dtype), k)
        v = jnp.where(w, v_new[:, None].astype(v.dtype), v)
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    valid = jnp.arange(slots * page)[None, :] < seq_lens[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def chunk_prefill_attention_ref(q, k_pages, v_pages, block_table, positions):
    """Chunked-prefill attention: a fixed-width chunk of C query tokens per
    sequence attends to everything already written to its arena pages
    (earlier chunks AND this chunk's own K/V, which the caller scatters in
    before attending) under a causal mask on absolute positions.

    q [B,C,H,hd]; pages [n_pages, page, Hkv, hd]; block_table [B, slots];
    positions [B,C] int32 absolute positions of the chunk's tokens (pad rows
    may repeat a position — they attend somewhere valid and are discarded).
    -> [B,C,H,hd].
    """
    B, C, H, hd = q.shape
    n_pages, page, Hkv, _ = k_pages.shape
    slots = block_table.shape[1]
    k = k_pages[block_table].reshape(B, slots * page, Hkv, hd)
    v = v_pages[block_table].reshape(B, slots * page, Hkv, hd)
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (hd ** -0.5)
    kpos = jnp.arange(slots * page)
    mask = positions[:, :, None] >= kpos[None, None, :]
    s = jnp.where(mask[:, None], s, -jnp.inf)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bkhd->bqhd", (p / l).astype(v.dtype), v)


def ssd_chunk_ref(x, dt, A, Bm, Cm):
    """Sequential (non-chunked) SSD recurrence — the exact semantics:
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t.
    x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,H,N]."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp          # [B,H,P], [B,H], [B,H,N], [B,H,N]
        dA = jnp.exp(dtt * A[None, :])
        h = h * dA[..., None, None] + jnp.einsum("bhn,bhp->bhnp", bt,
                                                 xt * dtt[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          Bm.swapaxes(0, 1).astype(jnp.float32),
          Cm.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)
