"""Pallas TPU flash attention (prefill hot-spot).

Grid (batch, q_heads, q_blocks, kv_blocks); the kv dimension is innermost —
TPU executes the grid sequentially over it, so the online-softmax state
(m, l, acc) lives in VMEM scratch and persists across kv steps. K/V are
staged HBM->VMEM per (bq x bk) tile via BlockSpec; GQA is handled in the
K/V index_map (kv head = q head // group) so the cache is never repeated.

Block sizes default to 512x512 tiles with 128-lane head_dim — MXU-aligned
(multiples of 128 on both contracting dims).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, bq: int, bk: int,
                  n_kv_blocks: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (sequential innermost)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, :, 0, :]                      # [bq, hd]
        k = k_ref[0, :, 0, :]                      # [bk, hd]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = (acc_scr[...] * alpha
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    if causal:
        # skip fully-masked tiles (query block strictly before kv block)
        pl.when(j * bk <= (i + 1) * bq - 1)(_compute)
    else:
        _compute()

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_scr[...]
                             / jnp.maximum(l_scr[...], 1e-30)
                             ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q [B, Sq, H, hd]; k/v [B, Sk, Hkv, hd] (Hkv divides H). -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, bq=bq, bk=bk,
        n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
