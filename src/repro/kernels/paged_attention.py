"""Pallas TPU paged decode attention — the serving hot-spot behind Maestro's
elastic KV pool (§III.C spatial multiplexing).

One query token per sequence attends to its KV pages through a block table.
Grid (batch, page_slots); the page slot dimension is innermost/sequential, so
online-softmax state persists in VMEM scratch. The block table and per-seq
lengths are scalar-prefetched (PrefetchScalarGridSpec) and drive the K/V page
BlockSpec index_maps — pages are fetched HBM->VMEM exactly once, in block-
table order, with no gather materialization.

GQA: q [B, H, hd] is grouped as [Hkv, g, hd] inside the kernel; K/V pages
keep their native [page, Hkv, hd] layout (never repeated).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_table, seq_lens, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, page_size: int,
                  n_slots: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(1)          # page slot (sequential)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = seq_lens[b]
    n_used = pl.cdiv(seq_len, page_size)

    @pl.when(s < n_used)
    def _compute():
        q = q_ref[0]                                   # [H, hd]
        k = k_ref[0]                                   # [page, Hkv, hd]
        v = v_ref[0]
        if kn_ref is not None:
            # inline new-token K/V: splice the current token's row into the
            # page block that holds position seq_len - 1, so the write is
            # visible to this very iteration's read without a page-store
            # scatter ordered before the kernel (decode-horizon hook). The
            # spliced block is elementwise identical to scatter-then-read.
            w_pos = seq_len - 1
            sel = jax.lax.broadcasted_iota(
                jnp.int32, k.shape, 0) == (w_pos % page_size)
            hit = (s == w_pos // page_size)
            k = jnp.where(sel & hit, kn_ref[0].astype(k.dtype), k)
            v = jnp.where(sel & hit, vn_ref[0].astype(v.dtype), v)
        H, hd = q.shape
        Hkv = k.shape[1]
        g = H // Hkv
        qg = q.reshape(Hkv, g, hd).astype(jnp.float32)
        kf = k.astype(jnp.float32)
        # scores [Hkv, g, page]
        sc = jax.lax.dot_general(
            qg, kf, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale
        pos = s * page_size + jax.lax.broadcasted_iota(
            jnp.int32, sc.shape, 2)
        sc = jnp.where(pos < seq_len, sc, NEG_INF)
        m_prev = m_scr[...]                            # [Hkv, g, 1]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=2, keepdims=True))
        p = jnp.exp(sc - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=2, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p, v.astype(jnp.float32), (((2,), (0,)), ((0,), (1,))))
        acc_scr[...] = acc_scr[...] * alpha + pv       # [Hkv, g, hd]

    @pl.when(s == n_slots - 1)
    def _finalize():
        acc = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        H, hd = o_ref.shape[1], o_ref.shape[2]
        o_ref[0] = acc.reshape(H, hd).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "interpret"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_table: jax.Array, seq_lens: jax.Array,
                    page_size: int = 64, interpret: bool = False,
                    k_new: jax.Array | None = None,
                    v_new: jax.Array | None = None) -> jax.Array:
    """q [B, H, hd]; {k,v}_pages [n_pages, page_size, Hkv, hd];
    block_table [B, max_slots] int32; seq_lens [B] int32. -> [B, H, hd].

    seq_lens is clamped to >= 1: with n_used == 0 no compute block would run
    and the finalize step would divide a zero accumulator — callers with idle
    rows (the serving engine's free decode slots) point them at a null page.

    ``k_new``/``v_new`` [B, Hkv, hd] (optional): the current token's K/V,
    made visible at position ``seq_len - 1`` inside the kernel instead of
    requiring a page-store scatter sequenced before the call — the decode
    horizon's in-loop read-your-own-write path (see ``ref.paged_attention_ref``
    for the exact splice semantics; outputs are bitwise identical to
    scatter-then-attend for live lanes).
    """
    B, H, hd = q.shape
    seq_lens = jnp.maximum(seq_lens, 1)
    Hkv = k_pages.shape[2]
    n_slots = block_table.shape[1]
    grid = (B, n_slots)
    inline = k_new is not None
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               n_slots=n_slots, scale=hd ** -0.5)
    if not inline:
        def kernel(bt, sl, q_r, k_r, v_r, o_r, m_s, l_s, a_s):  # noqa: F811
            _paged_kernel(bt, sl, q_r, k_r, v_r, None, None, o_r, m_s, l_s,
                          a_s, page_size=page_size, n_slots=n_slots,
                          scale=hd ** -0.5)
    in_specs = [
        pl.BlockSpec((1, H, hd), lambda b, s, bt, sl: (b, 0, 0)),
        pl.BlockSpec((1, page_size, Hkv, hd),
                     lambda b, s, bt, sl: (bt[b, s], 0, 0, 0)),
        pl.BlockSpec((1, page_size, Hkv, hd),
                     lambda b, s, bt, sl: (bt[b, s], 0, 0, 0)),
    ]
    operands = [q, k_pages, v_pages]
    if inline:
        in_specs += [
            pl.BlockSpec((1, Hkv, hd), lambda b, s, bt, sl: (b, 0, 0)),
            pl.BlockSpec((1, Hkv, hd), lambda b, s, bt, sl: (b, 0, 0)),
        ]
        operands += [k_new, v_new]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, hd), lambda b, s, bt, sl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, H // Hkv, 1), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv, 1), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv, hd), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret)
    return fn(block_table, seq_lens, *operands)
