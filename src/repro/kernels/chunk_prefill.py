"""Pallas TPU chunked-prefill attention — the prefill half of the engine's
fused iteration loop (continuous batching with chunked prefill).

A fixed-width chunk of C prompt tokens per sequence attends to everything
already written to its arena pages — earlier chunks of the same prompt and
the current chunk's own K/V, which the caller scatters into the pages before
attending — under a causal mask on absolute token positions. The fixed
[B, C] query shape is the whole point: every chunk of every prompt reuses
one compiled executable, killing the per-prompt-length recompiles of
monolithic prefill.

Grid (batch, page_slots); the page-slot dimension is innermost/sequential so
online-softmax state persists in VMEM scratch, exactly like
``paged_attention``. The block table and per-sequence visible-KV lengths are
scalar-prefetched and drive the K/V page BlockSpec index maps. GQA: q
[B, C, H, hd] is regrouped to [Hkv, C*g, hd] inside the kernel; K/V pages
keep their native [page, Hkv, hd] layout (never repeated).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _chunk_kernel(block_table, k_lens, q_ref, pos_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size: int, n_slots: int,
                  scale: float):
    b = pl.program_id(0)
    s = pl.program_id(1)          # page slot (sequential)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    n_used = pl.cdiv(k_lens[b], page_size)

    @pl.when(s < n_used)
    def _compute():
        q = q_ref[0]                                   # [C, H, hd]
        k = k_ref[0]                                   # [page, Hkv, hd]
        v = v_ref[0]
        C, H, hd = q.shape
        Hkv = k.shape[1]
        g = H // Hkv
        # head h = kvh*g + sub (jnp.repeat order) -> rows grouped by kv head
        qg = (q.reshape(C, Hkv, g, hd).transpose(1, 0, 2, 3)
              .reshape(Hkv, C * g, hd).astype(jnp.float32))
        kf = k.astype(jnp.float32)
        # scores [Hkv, C*g, page]
        sc = jax.lax.dot_general(
            qg, kf, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale
        qpos = jnp.repeat(pos_ref[0], g)               # [C*g]
        kpos = s * page_size + jax.lax.broadcasted_iota(
            jnp.int32, sc.shape, 2)
        sc = jnp.where(qpos[None, :, None] >= kpos, sc, NEG_INF)
        m_prev = m_scr[...]                            # [Hkv, C*g, 1]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=2, keepdims=True))
        p = jnp.exp(sc - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=2, keepdims=True)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p, v.astype(jnp.float32), (((2,), (0,)), ((0,), (1,))))
        acc_scr[...] = acc_scr[...] * alpha + pv       # [Hkv, C*g, hd]

    @pl.when(s == n_slots - 1)
    def _finalize():
        acc = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        C, H, hd = o_ref.shape[1], o_ref.shape[2], o_ref.shape[3]
        Hkv = acc.shape[0]
        o_ref[0] = (acc.reshape(Hkv, C, H // Hkv, hd).transpose(1, 0, 2, 3)
                    .reshape(C, H, hd).astype(o_ref.dtype))


@functools.partial(jax.jit,
                   static_argnames=("page_size", "interpret"))
def chunk_prefill_attention(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, block_table: jax.Array,
                            positions: jax.Array, page_size: int = 64,
                            interpret: bool = False) -> jax.Array:
    """q [B, C, H, hd]; {k,v}_pages [n_pages, page_size, Hkv, hd];
    block_table [B, max_slots] int32; positions [B, C] int32 absolute
    positions of the chunk tokens. -> [B, C, H, hd].

    The caller must have scattered this chunk's K/V into the pages already;
    per-sequence visible KV length is ``max(positions) + 1`` (pad rows repeat
    position 0 and attend harmlessly to the first written token).
    """
    B, C, H, hd = q.shape
    Hkv = k_pages.shape[2]
    n_slots = block_table.shape[1]
    k_lens = jnp.max(positions, axis=1) + 1
    grid = (B, n_slots)
    kernel = functools.partial(_chunk_kernel, page_size=page_size,
                               n_slots=n_slots, scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, H, hd), lambda b, s, bt, kl: (b, 0, 0, 0)),
            pl.BlockSpec((1, C), lambda b, s, bt, kl: (b, 0)),
            pl.BlockSpec((1, page_size, Hkv, hd),
                         lambda b, s, bt, kl: (bt[b, s], 0, 0, 0)),
            pl.BlockSpec((1, page_size, Hkv, hd),
                         lambda b, s, bt, kl: (bt[b, s], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, H, hd),
                               lambda b, s, bt, kl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, C * (H // Hkv), 1), jnp.float32),
            pltpu.VMEM((Hkv, C * (H // Hkv), 1), jnp.float32),
            pltpu.VMEM((Hkv, C * (H // Hkv), hd), jnp.float32),
        ],
    )
    fn = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, hd), q.dtype),
        interpret=interpret)
    return fn(block_table, k_lens, q, positions, k_pages, v_pages)
