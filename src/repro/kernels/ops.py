"""Jitted dispatch wrappers: Pallas kernel on TPU, interpret-mode Pallas for
CPU validation, jnp reference as the portable fallback.

``use_pallas()`` decides per-backend; models call these wrappers so the same
code path serves the TPU production build, the CPU dry-run (jnp) and the
interpret-mode kernel tests.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref
from repro.kernels import ssd_chunk as _ssd

_FORCE = {"mode": None}   # None=auto | "pallas" | "interpret" | "ref"


def set_mode(mode):
    assert mode in (None, "pallas", "interpret", "ref")
    _FORCE["mode"] = mode


def _mode() -> str:
    if _FORCE["mode"]:
        return _FORCE["mode"]
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, causal: bool = True, **kw):
    m = _mode()
    if m == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, **kw)
    if m == "interpret":
        return _fa.flash_attention(q, k, v, causal=causal, interpret=True, **kw)
    return _ref.flash_attention_ref(q, k, v, causal=causal)


def paged_attention(q, k_pages, v_pages, block_table, seq_lens, **kw):
    m = _mode()
    if m == "pallas":
        return _pa.paged_attention(q, k_pages, v_pages, block_table,
                                   seq_lens, **kw)
    if m == "interpret":
        return _pa.paged_attention(q, k_pages, v_pages, block_table,
                                   seq_lens, interpret=True, **kw)
    return _ref.paged_attention_ref(q, k_pages, v_pages, block_table, seq_lens)


def ssd_chunk(x, dt, A, Bm, Cm, **kw):
    m = _mode()
    if m == "pallas":
        return _ssd.ssd_chunk(x, dt, A, Bm, Cm, **kw)
    if m == "interpret":
        return _ssd.ssd_chunk(x, dt, A, Bm, Cm, interpret=True, **kw)
    return _ref.ssd_chunk_ref(x, dt, A, Bm, Cm)
