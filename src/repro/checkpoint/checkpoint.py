"""Fault-tolerant checkpointing: atomic save (tmp + rename), optional async
host-side write, and ELASTIC restore — a checkpoint written under one mesh
can be restored onto a different mesh (re-sharding happens at device_put
against the target NamedShardings), which is what elastic scaling needs.

Format: <dir>/step_<n>/ with arrays.npz (flat leaves) + manifest.json
(treedef + shapes + dtypes + step metadata).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


def save(path: str, tree, step: int, extra: Optional[Dict] = None,
         async_: bool = False) -> Optional[threading.Thread]:
    """Atomic checkpoint: write to <path>/.tmp_step_<n>, fsync, rename."""
    base = Path(path)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    arrays, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
        else None,
        "n_leaves": len(arrays),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }

    def _write():
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(path: str) -> Optional[int]:
    base = Path(path)
    if not base.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in base.glob("step_*"))
    return steps[-1] if steps else None


def restore(path: str, like_tree, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like_tree``. ``shardings`` (a matching
    tree of NamedSharding / None) re-shards for the CURRENT mesh — restoring
    a 256-chip checkpoint onto 512 chips (or 1 CPU) just works."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = Path(path) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        arrays = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    leaves, treedef = jax.tree_util.tree_flatten(like_tree)
    assert len(leaves) == len(arrays), (len(leaves), len(arrays))
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(a.astype(l.dtype), s)
               for a, l, s in zip(arrays, leaves, shard_leaves)]
    else:
        out = [jax.device_put(a.astype(l.dtype)) for a, l in
               zip(arrays, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def prune(path: str, keep: int = 3) -> None:
    base = Path(path)
    steps = sorted(base.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p)
