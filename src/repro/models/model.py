"""Public model API: ``build_model(cfg_or_name, mesh=None)``.

The returned ``Model`` exposes:
  param_defs / init / abstract_params / param_pspecs
  loss(params, tokens, labels, extras)      — training objective
  prefill(params, tokens, extras)           — (last logits, prompt cache)
  decode_step(params, cache, tokens, pos)   — (logits, new cache)
  cache_specs(batch, seq)                   — decode-cache abstract tree
"""
from __future__ import annotations

from typing import Union

from repro.configs.base import ArchConfig, get_config
from repro.models.transformer import Model


def build_model(cfg: Union[str, ArchConfig], mesh=None,
                mode: str = "tp_sp") -> Model:
    if isinstance(cfg, str):
        cfg = get_config(cfg)
    return Model(cfg, mesh=mesh, mode=mode)
