"""Mamba2 (SSD — state-space duality) block: chunked full-sequence scan +
single-token decode step.

Full path follows the SSD chunked algorithm (arXiv:2405.21060 §6): the sequence
is split into chunks of Q tokens; within a chunk the output is an attention-like
masked matmul (quadratic in Q only), and chunk-to-chunk state is carried through
a lax.scan (linear in sequence length) — this is what makes ``long_500k``
in-contract for the ssm/hybrid archs.

Sharding: heads over "tp", batch over "batch"; the recurrent state
[B, H, dstate, headdim] is tiny and stays head-sharded.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.common import Leaf, rms_norm
from repro.models import flags


def ssm_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    s = cfg.ssm
    D, dt = cfg.d_model, cfg.dtype
    di = s.d_inner(D)
    H = s.n_heads(D)
    GN = s.n_groups * s.d_state
    return {
        "ln": Leaf((D,), (None,), dt, init="ones"),
        "wx": Leaf((D, di), ("fsdp", "tp"), dt),
        "wz": Leaf((D, di), ("fsdp", "tp"), dt),
        "wB": Leaf((D, GN), ("fsdp", None), dt),
        "wC": Leaf((D, GN), ("fsdp", None), dt),
        "wdt": Leaf((D, H), ("fsdp", "tp"), dt),
        "conv": Leaf((s.conv_dim, di), (None, "tp"), dt, scale=0.5),
        "A_log": Leaf((H,), ("tp",), jnp.float32, init="zeros"),
        "dt_bias": Leaf((H,), ("tp",), jnp.float32, init="zeros"),
        "D_skip": Leaf((H,), ("tp",), jnp.float32, init="ones"),
        "gn": Leaf((di,), ("tp",), dt, init="ones"),
        "wout": Leaf((di, D), ("tp", "fsdp"), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x [B,S,C]; w [width,C]."""
    width = w.shape[0]
    out = x * w[width - 1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[width - 1 - i]
    return out


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD. xh [B,S,H,P]; dt [B,S,H] (post-softplus); A [H] (negative);
    Bm/Cm [B,S,H,N] (already head-broadcast). Returns y [B,S,H,P] (f32 math).

    Recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    """
    B, S, H, P_ = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    # slice per chunk INSIDE the scan body (closure capture, dynamic_slice)
    # rather than stacking reshaped-f32 copies as scan xs — the stacked-xs
    # form materializes a full-sequence f32 copy of x/B/C per layer, which
    # was the dominant HBM peak for the ssm/hybrid train cells
    dt32 = dt.astype(jnp.float32)

    def _chunk(a, c):
        return lax.dynamic_slice_in_dim(a, c * Q, Q, axis=1)

    def step(state, c):
        xc = _chunk(xh, c).astype(jnp.float32)   # [B,Q,H,P]
        dc = _chunk(dt32, c)                     # [B,Q,H]
        bc = _chunk(Bm, c).astype(jnp.float32)   # [B,Q,H,N]
        cc = _chunk(Cm, c).astype(jnp.float32)
        dA = dc * A                              # [B,Q,H]
        dA_cs = jnp.cumsum(dA, axis=1)          # inclusive
        xdt = xc * dc[..., None]
        # intra-chunk (masked quadratic term)
        scores = jnp.einsum("bqhn,bkhn->bqkh", cc, bc)
        L = jnp.exp(dA_cs[:, :, None, :] - dA_cs[:, None, :, :])
        iq = jnp.arange(Q)
        L = jnp.where((iq[:, None] >= iq[None, :])[None, :, :, None], L, 0.0)
        y = jnp.einsum("bqkh,bkhp->bqhp", scores * L, xdt)
        # inter-chunk (contribution of carried state)
        y = y + jnp.einsum("bqhn,bhnp->bqhp", cc * jnp.exp(dA_cs)[..., None], state)
        # new carried state
        decay_to_end = jnp.exp(dA_cs[:, -1:, :] - dA_cs)        # [B,Q,H]
        new_state = (state * jnp.exp(dA_cs[:, -1])[..., None, None]
                     + jnp.einsum("bkhn,bkhp->bhnp", bc * decay_to_end[..., None], xdt))
        return new_state, y

    state0 = jnp.zeros((B, H, N, P_), jnp.float32)
    # checkpoint: recompute intra-chunk decay/score tensors in backward rather
    # than stacking [nc,B,Q,Q,H] residuals across the chunk scan
    final_state, ys = flags.scan(jax.checkpoint(step), state0,
                                 jnp.arange(nc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P_)
    return y.astype(xh.dtype), final_state


def _pre(p, x, cfg: ArchConfig, ctx: ShardingCtx):
    """Shared projections: returns (xz [B,S,di], z, Bm/Cm [B,S,H,N], dt [B,S,H])."""
    s = cfg.ssm
    H = s.n_heads(cfg.d_model)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = h @ p["wx"]
    z = h @ p["wz"]
    Bm = (h @ p["wB"]).reshape(*h.shape[:-1], s.n_groups, s.d_state)
    Cm = (h @ p["wC"]).reshape(*h.shape[:-1], s.n_groups, s.d_state)
    if s.n_groups != H:
        Bm = jnp.repeat(Bm, H // s.n_groups, axis=-2)
        Cm = jnp.repeat(Cm, H // s.n_groups, axis=-2)
    dt = jax.nn.softplus((h @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    return xz, z, Bm, Cm, dt


def _post(p, y, z, x_shape, cfg: ArchConfig, ctx: ShardingCtx):
    """Gated RMS norm + out projection. y [B,S,di]."""
    y = rms_norm(y * jax.nn.silu(z), p["gn"], cfg.norm_eps)
    out = y @ p["wout"]
    return ctx.cs(out, "batch", "sp", None)


def ssm_full(p, x, cfg: ArchConfig, ctx: ShardingCtx, want_cache: bool = False
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence Mamba2 block. Returns (out [B,S,D], cache {state, conv})."""
    s = cfg.ssm
    B, S, _ = x.shape
    H, P_ = s.n_heads(cfg.d_model), s.head_dim
    xz, z, Bm, Cm, dt = _pre(p, x, cfg, ctx)
    xc = jax.nn.silu(_causal_conv(xz, p["conv"]))
    xc = ctx.cs(xc, "batch", None, "tp")
    xh = xc.reshape(B, S, H, P_)
    A = -jnp.exp(p["A_log"])
    y, final_state = _ssd_chunk_scan(xh, dt, A, Bm, Cm, s.chunk)
    y = y + xh * p["D_skip"][None, None, :, None].astype(xh.dtype)
    out = _post(p, y.reshape(B, S, -1), z, x.shape, cfg, ctx)
    cache = None
    if want_cache:
        # decode cache: recurrent state + last (conv_dim-1) pre-conv inputs
        conv_tail = xz[:, -(s.conv_dim - 1):, :]
        cache = {"state": ctx.cs(final_state, "batch", "tp", None, None),
                 "conv": ctx.cs(conv_tail, "batch", None, "tp")}
    return out, cache


def ssm_decode(p, x, cache: Dict[str, jax.Array], cfg: ArchConfig,
               ctx: ShardingCtx) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token Mamba2 step. x [B,1,D]; cache {state [B,H,N,P], conv [B,w-1,di]}."""
    s = cfg.ssm
    B = x.shape[0]
    H, P_ = s.n_heads(cfg.d_model), s.head_dim
    xz, z, Bm, Cm, dt = _pre(p, x, cfg, ctx)          # xz [B,1,di]; dt [B,1,H]
    # conv over the buffered window
    win = jnp.concatenate([cache["conv"], xz], axis=1)     # [B,w,di]
    xc = jax.nn.silu(jnp.sum(win * p["conv"][None], axis=1, keepdims=True))
    xh = xc.reshape(B, H, P_).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dt1 = dt[:, 0]                                          # [B,H]
    dA = jnp.exp(dt1 * A)                                   # [B,H]
    b1 = Bm[:, 0].astype(jnp.float32)                       # [B,H,N]
    c1 = Cm[:, 0].astype(jnp.float32)
    xdt = xh * dt1[..., None]                               # [B,H,P]
    new_state = (cache["state"] * dA[..., None, None]
                 + jnp.einsum("bhn,bhp->bhnp", b1, xdt))
    y = jnp.einsum("bhn,bhnp->bhp", c1, new_state)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.astype(x.dtype).reshape(B, 1, -1)
    out = _post(p, y, z, x.shape, cfg, ctx)
    cache = {"state": ctx.cs(new_state, "batch", "tp", None, None),
             "conv": ctx.cs(win[:, 1:], "batch", None, "tp")}
    return out, cache
