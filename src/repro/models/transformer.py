"""Model assembly: heterogeneous layer slots, scan-over-groups execution,
embedding / LM head, chunked cross-entropy, prefill & decode paths, and the
Whisper-style encoder.

Layer heterogeneity (dense / MoE / SSM / hybrid / cross-attn) is expressed as a
repeating *period* of layer slots (``cfg.layer_pattern_period``); parameters of
repeated groups are stacked on a leading "stack" axis and executed with
``lax.scan`` (keeps HLO size O(period), compile time flat in depth, and remat
boundaries exactly at group edges).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, input_specs
from repro.distributed.sharding import ShardingCtx, mesh_rules
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models.common import (Leaf, abstract_tree, init_tree, pad_vocab,
                                 pspec_tree, rms_norm)
from repro.models import flags

SlotKind = Tuple[str, str, bool]  # (mixer, ffn, has_cross)


def slot_kinds(cfg: ArchConfig) -> List[SlotKind]:
    kinds = []
    for i in range(cfg.layer_pattern_period):
        mixer = "attn" if cfg.is_attn_layer(i) else "ssm"
        if cfg.is_moe_layer(i):
            ffn = "moe"
        elif cfg.family == "ssm" or (cfg.family == "hybrid" and mixer == "ssm"):
            ffn = "none"
        else:
            ffn = "dense"
        cross = cfg.is_cross_layer(i) or cfg.family == "encdec"
        kinds.append((mixer, ffn, cross))
    return kinds


def _stack(defs, g: int):
    """Prepend a stacked-group dim to every Leaf."""
    return jax.tree_util.tree_map(
        lambda l: dataclasses.replace(l, shape=(g,) + l.shape,
                                      axes=("stack",) + l.axes),
        defs, is_leaf=lambda x: isinstance(x, Leaf))


class Model:
    """Pure-functional model bound to one ArchConfig (+ optional mesh)."""

    def __init__(self, cfg: ArchConfig, mesh=None, mode: str = "tp_sp"):
        self.cfg = cfg
        self.ctx = ShardingCtx(mesh, mode=mode)
        self.kinds = slot_kinds(cfg)
        self.period = cfg.layer_pattern_period
        assert cfg.n_layers % self.period == 0, (cfg.name, cfg.n_layers, self.period)
        self.n_groups = cfg.n_layers // self.period
        self.vocab_padded = pad_vocab(cfg.vocab, 256)
        self._defs = self._build_defs()

    # ------------------------------------------------------------------ defs
    def _slot_defs(self, kind: SlotKind) -> Dict[str, Any]:
        cfg = self.cfg
        mixer, ffn, cross = kind
        d: Dict[str, Any] = {}
        if mixer == "attn":
            d["attn"] = L.attn_defs(cfg)
        else:
            d["ssm"] = M2.ssm_defs(cfg)
        if cross:
            d["cross"] = L.attn_defs(cfg, cross=True)
        if ffn == "dense":
            d["ffn"] = L.ffn_defs(cfg, gelu=cfg.ffn_gelu)
        elif ffn == "moe":
            d["moe"] = MOE.moe_defs(cfg)
        return d

    def _build_defs(self):
        cfg = self.cfg
        D, dt = cfg.d_model, cfg.dtype
        Vp = self.vocab_padded
        group = {f"slot{i}": self._slot_defs(k) for i, k in enumerate(self.kinds)}
        defs: Dict[str, Any] = {
            "embed": Leaf((Vp, D), ("tp", "fsdp"), dt, scale=1.0),
            "final_ln": Leaf((D,), (None,), dt, init="ones"),
            "groups": _stack(group, self.n_groups),
        }
        if not cfg.tie_embeddings:
            defs["lm_head"] = Leaf((D, Vp), ("fsdp", "tp"), dt)
        if cfg.encoder is not None:
            enc_layer = {
                "attn": L.attn_defs(cfg),
                "ffn": L.ffn_defs(cfg, gelu=True),
            }
            defs["encoder"] = {
                "layers": _stack(enc_layer, cfg.encoder.n_layers),
                "ln": Leaf((D,), (None,), dt, init="ones"),
            }
        return defs

    def param_defs(self):
        return self._defs

    def init(self, key, dtype_override=None):
        return init_tree(self._defs, key, dtype_override)

    def abstract_params(self, dtype_override=None):
        return abstract_tree(self._defs, dtype_override)

    def param_pspecs(self):
        return pspec_tree(self._defs, mesh_rules(self.ctx.mesh))

    # -------------------------------------------------------------- embedding
    def embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.ctx.mode == "fsdp_cp" and tokens.shape[1] == 1:
            return self.ctx.cs(x, None, None, "fsdp")  # stationary decode
        return self.ctx.cs(x, "batch", "sp", None)

    def unembed_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # ---------------------------------------------------------------- encoder
    def encode(self, params, frames):
        """Whisper-style encoder over precomputed frame embeddings [B,F,D]."""
        cfg = self.cfg
        pos = jnp.arange(frames.shape[1])[None, :]

        def body(x, lp):
            o, _ = L.attn_full(lp["attn"], x, cfg, self.ctx, pos, causal=False)
            x = x + o
            x = x + L.ffn_apply(lp["ffn"], x, cfg, self.ctx, gelu=True)
            return x, None

        x, _ = flags.scan(jax.checkpoint(body), frames, params["encoder"]["layers"])
        return rms_norm(x, params["encoder"]["ln"], cfg.norm_eps)

    # ------------------------------------------------------------- full pass
    def _group_full(self, x, gp, positions, cross_src, want_cache: bool):
        cfg, ctx = self.cfg, self.ctx
        caches: Dict[str, Any] = {}
        for i, (mixer, ffn, cross) in enumerate(self.kinds):
            sp = gp[f"slot{i}"]
            if mixer == "attn":
                o, c = L.attn_full(sp["attn"], x, cfg, ctx, positions,
                                   want_cache=want_cache)
            else:
                o, c = M2.ssm_full(sp["ssm"], x, cfg, ctx, want_cache=want_cache)
            x = x + o
            if want_cache:
                caches[f"slot{i}"] = c
            if cross:
                o, cc = L.attn_full(sp["cross"], x, cfg, ctx, positions,
                                    kv_src=cross_src, use_rope=False,
                                    want_cache=want_cache)
                x = x + o
                if want_cache:
                    caches[f"slot{i}_cross"] = cc
            if ffn == "dense":
                x = x + L.ffn_apply(sp["ffn"], x, cfg, ctx, gelu=cfg.ffn_gelu)
            elif ffn == "moe":
                x = x + MOE.moe_apply(sp["moe"], x, cfg, ctx)
        return x, caches

    def backbone(self, params, tokens, extras=None, want_cache=False,
                 remat=True):
        """tokens [B,S] -> final-normed hidden [B,S,D] (+ caches if asked)."""
        cfg = self.cfg
        extras = extras or {}
        x = self.embed(params, tokens)
        positions = jnp.arange(tokens.shape[1])[None, :]
        cross_src = None
        if cfg.encoder is not None:
            cross_src = self.encode(params, extras["frames"])
        elif cfg.cross_attn is not None:
            cross_src = extras["ctx_embeds"]

        def body(x, gp):
            x, caches = self._group_full(x, gp, positions, cross_src, want_cache)
            return x, caches if want_cache else None

        if remat:
            body = jax.checkpoint(body)
        x, caches = flags.scan(body, x, params["groups"])
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        if want_cache:
            return x, caches
        return x

    # ----------------------------------------------------------------- loss
    def loss(self, params, tokens, labels, extras=None):
        """Mean next-token cross-entropy, chunked over the sequence so the
        [B,S,V] logits are never materialized at once."""
        hidden = self.backbone(params, tokens, extras)
        hidden = self.ctx.cs(hidden, "batch", None, None)
        W = self.unembed_weight(params)
        B, S, D = hidden.shape
        Vp = self.vocab_padded
        cq = min(512, S)
        while S % cq:
            cq -= 1
        nc = S // cq
        hs = hidden.reshape(B, nc, cq, D).swapaxes(0, 1)
        ls = labels.reshape(B, nc, cq).swapaxes(0, 1)

        def step(acc, inp):
            hc, lc = inp
            logits = (hc @ W).astype(jnp.float32)          # [B,cq,Vp]
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.sum(logits * jax.nn.one_hot(lc, Vp, dtype=logits.dtype),
                         axis=-1)
            return acc + jnp.sum(lse - ll), None

        # checkpoint: recompute the [B,cq,V] logits chunk in backward instead
        # of saving every chunk's logits (that would be the full [B,S,V])
        total, _ = flags.scan(jax.checkpoint(step),
                              jnp.zeros((), jnp.float32), (hs, ls))
        return total / (B * S)

    # --------------------------------------------------------------- prefill
    def prefill(self, params, tokens, extras=None):
        """Returns (last-token logits [B,Vp], cache over the prompt)."""
        hidden, caches = self.backbone(params, tokens, extras, want_cache=True,
                                       remat=False)
        last = hidden[:, -1]
        logits = (last @ self.unembed_weight(params)).astype(jnp.float32)
        return logits, caches

    @property
    def supports_prefix_reuse(self) -> bool:
        """Prefix-cache eligibility: suffix prefill is only defined for
        pure causal self-attention stacks (every slot an attn mixer, no
        cross-attention, no encoder). SSM/hybrid state is positionally
        recurrent and cannot resume from cached pages."""
        cfg = self.cfg
        return (cfg.encoder is None and cfg.cross_attn is None
                and all(mx == "attn" and not cross
                        for mx, _, cross in self.kinds))

    def prefill_suffix(self, params, tokens, prefix_k, prefix_v):
        """Prefill only the unmatched suffix of a prompt.

        ``tokens`` [B,S] are the suffix tokens; ``prefix_k``/``prefix_v``
        [L,P,Hkv,hd] the cached prefix KV in the arena's stacked-layer
        layout (slot ``a``, group ``g`` at layer ``a * n_groups + g``, the
        same layout :meth:`paged_kv_layout` publishes). Per-row arithmetic
        matches :meth:`prefill` exactly (see ``layers.attn_suffix``), so the
        resulting logits and suffix KV are bitwise identical to a full
        prefill of prefix+suffix. Returns (last-token logits [B,Vp] f32,
        k_sfx, v_sfx [L,B,S,Hkv,hd]).
        """
        assert self.supports_prefix_reuse, self.cfg.name
        cfg = self.cfg
        A, G = len(self.kinds), self.n_groups
        P_pre = prefix_k.shape[1]
        S = tokens.shape[1]
        x = self.embed(params, tokens)
        positions = P_pre + jnp.arange(S)[None, :]
        # [A*G, P, Hkv, hd] -> [G, A, P, Hkv, hd] so groups scan on axis 0
        shp = (A, G) + prefix_k.shape[1:]
        pk_gs = prefix_k.reshape(shp).transpose(1, 0, 2, 3, 4)
        pv_gs = prefix_v.reshape(shp).transpose(1, 0, 2, 3, 4)

        def body(x, inp):
            gp, pk_g, pv_g = inp
            ks, vs = [], []
            for i, (mixer, ffn, _) in enumerate(self.kinds):
                sp = gp[f"slot{i}"]
                o, k_new, v_new = L.attn_suffix(sp["attn"], x, cfg, self.ctx,
                                                positions, pk_g[i], pv_g[i])
                x = x + o
                ks.append(k_new)
                vs.append(v_new)
                if ffn == "dense":
                    x = x + L.ffn_apply(sp["ffn"], x, cfg, self.ctx,
                                        gelu=cfg.ffn_gelu)
                elif ffn == "moe":
                    x = x + MOE.moe_apply(sp["moe"], x, cfg, self.ctx)
            return x, (jnp.stack(ks), jnp.stack(vs))

        x, (k_ys, v_ys) = flags.scan(body, x, (params["groups"], pk_gs, pv_gs))
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = (x[:, -1] @ self.unembed_weight(params)).astype(jnp.float32)
        # ys [G, A, B, S, Hkv, hd] -> stacked-layer [A*G, B, S, Hkv, hd]
        k_sfx = k_ys.transpose(1, 0, 2, 3, 4, 5).reshape((A * G,) + k_ys.shape[2:])
        v_sfx = v_ys.transpose(1, 0, 2, 3, 4, 5).reshape((A * G,) + v_ys.shape[2:])
        return logits, k_sfx, v_sfx

    @property
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill shares the suffix-prefill eligibility rule: the
        whole prompt context must live in paged self-attention KV, so only
        pure causal self-attention stacks qualify (SSM/hybrid state is
        positionally recurrent and cannot resume mid-prompt)."""
        return self.supports_prefix_reuse

    def prefill_chunk(self, params, k_pages, v_pages, tokens, positions,
                      block_tables, rows, offs, last_idx, attend):
        """One fixed-width prefill chunk per sequence, through the PAGED
        arena.

        tokens/positions [B,C]: a chunk of each prompt at its absolute
        positions (pad columns repeat token/position 0 and scatter to the
        null row); block_tables [B,W] plane-row indices; rows/offs [B,C]
        write coordinates of the chunk tokens; last_idx [B] the in-chunk
        index of each sequence's last real token (its logit row — only
        meaningful for the chunk that completes a prompt). ``attend`` is the
        chunked-prefill attention bound once at engine construction. The
        fixed [B,C] shape is the recompile killer: every chunk of every
        prompt length reuses one traced executable. Returns (last-token
        logits [B,Vp] f32, k_pages, v_pages) — pages are donatable.
        """
        assert self.supports_chunked_prefill, self.cfg.name
        cfg = self.cfg
        bases, _, _, _, _ = self.paged_kv_layout()
        x = self.embed(params, tokens)

        def body(carry, inp):
            x, kp, vp = carry
            gp, g = inp
            for i, (mixer, ffn, _) in enumerate(self.kinds):
                sp = gp[f"slot{i}"]
                o, kp, vp = L.attn_chunk_paged(
                    sp["attn"], x, cfg, self.ctx, positions, kp, vp,
                    bases[f"slot{i}"] + g, block_tables, rows, offs, attend)
                x = x + o
                if ffn == "dense":
                    x = x + L.ffn_apply(sp["ffn"], x, cfg, self.ctx,
                                        gelu=cfg.ffn_gelu)
                elif ffn == "moe":
                    x = x + MOE.moe_apply(sp["moe"], x, cfg, self.ctx)
            return (x, kp, vp), None

        (x, k_pages, v_pages), _ = flags.scan(
            body, (x, k_pages, v_pages),
            (params["groups"], jnp.arange(self.n_groups)))
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        last = x[jnp.arange(tokens.shape[0]), last_idx]
        logits = (last @ self.unembed_weight(params)).astype(jnp.float32)
        return logits, k_pages, v_pages

    # ---------------------------------------------------------------- decode
    def _group_decode(self, x, gp, gc, positions):
        cfg, ctx = self.cfg, self.ctx
        new_c: Dict[str, Any] = {}
        for i, (mixer, ffn, cross) in enumerate(self.kinds):
            sp = gp[f"slot{i}"]
            if mixer == "attn":
                o, c = L.attn_decode(sp["attn"], x, gc[f"slot{i}"], cfg, ctx,
                                     positions)
            else:
                o, c = M2.ssm_decode(sp["ssm"], x, gc[f"slot{i}"], cfg, ctx)
            x = x + o
            new_c[f"slot{i}"] = c
            if cross:
                o, cc = L.attn_decode(sp["cross"], x, gc[f"slot{i}_cross"],
                                      cfg, ctx, positions, cross=True)
                x = x + o
                new_c[f"slot{i}_cross"] = cc
            if ffn == "dense":
                x = x + L.ffn_apply(sp["ffn"], x, cfg, ctx, gelu=cfg.ffn_gelu)
            elif ffn == "moe":
                x = x + MOE.moe_apply(sp["moe"], x, cfg, ctx)
        return x, new_c

    def decode_step(self, params, cache, tokens, positions):
        """One token for every sequence. tokens [B,1]; positions [B].
        Returns (logits [B,Vp] f32, new cache — same pytree/shapes, donatable).

        The cache travels as a scan CARRY with per-group dynamic slice/update
        (not as stacked xs/ys): carries alias their buffers across iterations,
        so the multi-GB cache is updated in place instead of being stacked
        into fresh output buffers (xs/ys form peaked at ~3x cache size).
        """
        x = self.embed(params, tokens)

        def body(carry, inp):
            x, cache = carry
            gp, g = inp
            gc = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, g, 0, keepdims=False),
                cache)
            x, new_c = self._group_decode(x, gp, gc, positions)
            cache = jax.tree.map(
                lambda a, n: lax.dynamic_update_index_in_dim(a, n, g, 0),
                cache, new_c)
            return (x, cache), None

        (x, new_cache), _ = flags.scan(
            body, (x, cache),
            (params["groups"], jnp.arange(self.n_groups)))
        x = rms_norm(x, params["final_ln"], self.cfg.norm_eps)
        last = x[:, 0]
        if self.ctx.mode == "fsdp_cp":
            # stationary unembed: psum a [B, V/tp] partial instead of
            # all-gathering the f32 lm_head (311MB/step for qwen1.5-110b)
            last = self.ctx.cs(last, None, "fsdp")
        logits = (last @ self.unembed_weight(params)).astype(jnp.float32)
        return logits, new_cache

    # ------------------------------------------------------- paged serving
    def paged_kv_layout(self):
        """Self-attention KV geometry for the serving arena.

        Returns ``(bases, n_layers, Hkv, hd, dtype)`` where ``bases`` maps
        each self-attention slot name to its layer base in the stacked plane
        layout: slot ``a`` (in slot order), group ``g`` lives at stacked
        layer ``a * n_groups + g``. ``n_layers == 0`` means nothing to page
        (pure-SSM model: recurrent state only).
        """
        attn = [f"slot{i}" for i, (mx, _, _) in enumerate(self.kinds)
                if mx == "attn"]
        bases = {s: a * self.n_groups for a, s in enumerate(attn)}
        return (bases, len(attn) * self.n_groups, self.cfg.n_kv_heads,
                self.cfg.head_dim_, self.cfg.dtype)

    def _group_decode_paged(self, x, gp, gc, g, kp, vp, block_tables,
                            seq_lens, rows, offs, positions, bases, attend,
                            inline=False):
        """_group_decode with self-attention KV read/written through arena
        pages; ``gc``/``new_c`` carry only the non-paged (SSM / cross)
        entries."""
        cfg, ctx = self.cfg, self.ctx
        new_c: Dict[str, Any] = {}
        for i, (mixer, ffn, cross) in enumerate(self.kinds):
            sp = gp[f"slot{i}"]
            if mixer == "attn":
                o, kp, vp = L.attn_decode_paged(
                    sp["attn"], x, cfg, ctx, positions, kp, vp,
                    bases[f"slot{i}"] + g, block_tables, seq_lens, rows,
                    offs, attend, inline=inline)
            else:
                o, c = M2.ssm_decode(sp["ssm"], x, gc[f"slot{i}"], cfg, ctx)
                new_c[f"slot{i}"] = c
            x = x + o
            if cross:
                o, cc = L.attn_decode(sp["cross"], x, gc[f"slot{i}_cross"],
                                      cfg, ctx, positions, cross=True)
                x = x + o
                new_c[f"slot{i}_cross"] = cc
            if ffn == "dense":
                x = x + L.ffn_apply(sp["ffn"], x, cfg, ctx, gelu=cfg.ffn_gelu)
            elif ffn == "moe":
                x = x + MOE.moe_apply(sp["moe"], x, cfg, ctx)
        return x, new_c, kp, vp

    def decode_step_paged(self, params, state_cache, k_pages, v_pages,
                          block_tables, seq_lens, rows, offs, tokens,
                          positions, attend, inline=False):
        """One token for every sequence through the PAGED KV arena.

        Mirrors :meth:`decode_step`, but self-attention KV lives in the
        shared node arena plane (``k_pages``/``v_pages``, written in place
        via scatter and read through per-sequence ``block_tables``);
        ``state_cache`` carries only SSM state/conv and static cross-attn
        entries (see :meth:`state_cache_specs`). ``attend`` is the paged
        attention implementation bound once at engine construction.
        Returns (logits [B,Vp] f32, state_cache, k_pages, v_pages) — all
        cache-like arguments are donatable.
        """
        bases, _, _, _, _ = self.paged_kv_layout()
        x = self.embed(params, tokens)

        def body(carry, inp):
            x, sc, kp, vp = carry
            gp, g = inp
            gc = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, g, 0, keepdims=False),
                sc)
            x, new_c, kp, vp = self._group_decode_paged(
                x, gp, gc, g, kp, vp, block_tables, seq_lens, rows, offs,
                positions, bases, attend, inline=inline)
            sc = jax.tree.map(
                lambda a, n: lax.dynamic_update_index_in_dim(a, n, g, 0),
                sc, new_c)
            return (x, sc, kp, vp), None

        (x, state_cache, k_pages, v_pages), _ = flags.scan(
            body, (x, state_cache, k_pages, v_pages),
            (params["groups"], jnp.arange(self.n_groups)))
        x = rms_norm(x, params["final_ln"], self.cfg.norm_eps)
        logits = (x[:, 0] @ self.unembed_weight(params)).astype(jnp.float32)
        return logits, state_cache, k_pages, v_pages

    @property
    def supports_decode_horizon(self) -> bool:
        """Multi-token decode-horizon eligibility: the horizon loop carries
        only paged pages + positions between iterations, so every layer's
        context must live in paged self-attention KV — the same pure causal
        self-attention condition as prefix reuse (SSM/hybrid state and
        cross-attention caches would need in-loop state threading; those
        models fall back to one-token steps)."""
        return self.supports_prefix_reuse

    def decode_horizon(self, params, state_cache, k_pages, v_pages,
                       block_tables, positions, last_tokens, live, rem, cap,
                       eos, s_max, *, attend, horizon: int,
                       page_tokens: int):
        """Run up to ``horizon`` greedy decode iterations entirely on device.

        One jitted program replaces ``horizon`` host round-trips: a
        ``lax.fori_loop`` whose body is exactly :meth:`decode_step_paged`
        (same per-lane arithmetic as the one-token engine path — greedy
        parity is structural, not approximate), with on-device argmax
        sampling, in-loop paged-KV writes (iteration ``h`` reads its own
        write inline and iterations ``> h`` read it from the pages), and a
        per-lane stop mask.

        block_tables [B, W] plane rows; positions [B] next write position;
        last_tokens [B] the token feeding iteration 0; live [B] bool lanes
        decoding this launch; rem [B] tokens until ``max_new``; cap [B]
        page-granted emission budget (freezes a lane WITHOUT finishing it —
        truncation backpressure stays host-decided); eos [B] end token or -1;
        s_max scalar sequence window. A lane freezes permanently once it
        emits its stage-final token (``rem``/``eos``/``s_max``, the same
        predicate the engine applies after each one-token step) or exhausts
        ``cap``; frozen lanes emit the -1 sentinel, write only to the null
        row, and attend over a clamped length-1 window whose output is
        discarded.

        Returns ``(tokens [B, horizon] int32 with -1 in frozen lanes,
        positions, state_cache, k_pages, v_pages)`` — ONE host sync fetches
        the token block; positions stay on device as the next launch's
        persistent buffer.
        """
        assert self.supports_decode_horizon, self.cfg.name
        B = block_tables.shape[0]
        lanes = jnp.arange(B)
        out0 = jnp.full((B, horizon), -1, jnp.int32)
        live = live.astype(jnp.bool_)

        def body(h, carry):
            out, live, pos, last, rem, cap, sc, kp, vp = carry
            adv = live.astype(jnp.int32)
            # frozen/idle lanes read+write the reserved null row (row 0),
            # exactly like the one-token path's idle slots
            rows = jnp.where(live, block_tables[lanes, pos // page_tokens], 0)
            offs = jnp.where(live, pos % page_tokens, 0)
            seq_lens = jnp.where(live, pos + 1, 1)
            logits, sc, kp, vp = self.decode_step_paged(
                params, sc, kp, vp, block_tables, seq_lens, rows, offs,
                last[:, None], pos, attend, inline=True)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out = out.at[:, h].set(jnp.where(live, nxt, -1))
            pos = pos + adv
            rem = rem - adv
            cap = cap - adv
            last = jnp.where(live, nxt, last)
            stop = ((rem <= 0) | ((eos >= 0) & (nxt == eos))
                    | (pos >= s_max - 1) | (cap <= 0))
            return (out, live & ~stop, pos, last, rem, cap, sc, kp, vp)

        out, live, positions, last_tokens, rem, cap, state_cache, k_pages, \
            v_pages = lax.fori_loop(
                0, horizon, body,
                (out0, live, positions, last_tokens, rem, cap, state_cache,
                 k_pages, v_pages))
        return out, positions, state_cache, k_pages, v_pages

    # ----------------------------------------------------------- cache specs
    def _slot_cache_spec(self, kind: SlotKind, batch: int, seq: int):
        """ShapeDtypeStruct + PartitionSpec for one slot's decode cache."""
        cfg, ctx = self.cfg, self.ctx
        mixer, _, cross = kind
        out = {}
        if mixer == "attn":
            Hkv, hd = cfg.n_kv_heads, cfg.head_dim_
            shp = (batch, seq, Hkv, hd)
            spec = ctx.spec("batch", "kv_sp", None, None, dims=shp)
            out["self"] = ({"k": (shp, cfg.dtype, spec),
                            "v": (shp, cfg.dtype, spec)})
        else:
            s = cfg.ssm
            H, Pd = s.n_heads(cfg.d_model), s.head_dim
            shp_s = (batch, H, s.d_state, Pd)
            shp_c = (batch, s.conv_dim - 1, s.d_inner(cfg.d_model))
            out["self"] = {
                "state": (shp_s, jnp.float32,
                          ctx.spec("batch", "tp", None, None, dims=shp_s)),
                "conv": (shp_c, cfg.dtype,
                         ctx.spec("batch", None, "tp", dims=shp_c)),
            }
        if cross:
            n_ctx = (cfg.encoder.n_frames if cfg.encoder is not None
                     else cfg.cross_attn.n_ctx_tokens)
            Hkv, hd = cfg.n_kv_heads, cfg.head_dim_
            shp = (batch, n_ctx, Hkv, hd)
            spec = ctx.spec("batch", "kv_sp", None, None, dims=shp)
            out["cross"] = {"k": (shp, cfg.dtype, spec),
                            "v": (shp, cfg.dtype, spec)}
        return out

    def cache_specs(self, batch: int, seq: int):
        """(ShapeDtypeStruct tree, PartitionSpec tree) for the decode cache."""
        g = self.n_groups
        structs: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}

        def expand(raw):  # (shape, dtype, spec) -> stacked struct/spec
            shp, dt, spec = raw
            return (jax.ShapeDtypeStruct((g,) + shp, dt),
                    P(*((None,) + tuple(spec))))

        for i, kind in enumerate(self.kinds):
            raw = self._slot_cache_spec(kind, batch, seq)
            for part, entries in raw.items():
                name = f"slot{i}" if part == "self" else f"slot{i}_cross"
                st, sp = {}, {}
                for kname, r in entries.items():
                    st[kname], sp[kname] = expand(r)
                structs[name] = st
                specs[name] = sp
        return structs, specs

    def state_cache_specs(self, batch: int, seq: int):
        """:meth:`cache_specs` minus self-attention K/V — those pages live in
        the serving arena; what remains (SSM state/conv, static cross-attn
        K/V) is the per-slot state an engine still holds densely."""
        structs, specs = self.cache_specs(batch, seq)
        for i, (mixer, _, _) in enumerate(self.kinds):
            if mixer == "attn":
                structs.pop(f"slot{i}", None)
                specs.pop(f"slot{i}", None)
        return structs, specs
