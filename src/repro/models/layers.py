"""Transformer building blocks: GQA attention (full / decode), cross-attention,
dense SwiGLU / GELU FFNs.

All functions are pure; sharding is injected via ``ShardingCtx`` constraints so
the same code runs unsharded in smoke tests and 512-way sharded in the dry-run.

Memory notes (these drive the roofline):
  * full attention is blockwise over q-chunks (online-softmax-free per chunk,
    each chunk's score matrix is [B, H, qc, Sk] — never the full S^2 matrix);
  * decode attention uses the grouped-GQA einsum (no repeat of the KV cache —
    repeating a 32k-seq cache 8x would be a multi-TB materialization);
  * KV caches are written with per-batch dynamic_update_slice so GSPMD keeps
    the sequence axis sharded (verified in the dry-run HLO).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.common import Leaf, apply_rope, rms_norm
from repro.models import flags


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def attn_defs(cfg: ArchConfig, cross: bool = False) -> Dict[str, Leaf]:
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    d_ctx = D
    if cross and cfg.cross_attn is not None and cfg.cross_attn.ctx_dim:
        d_ctx = cfg.cross_attn.ctx_dim
    dt = cfg.dtype
    defs: Dict[str, Leaf] = {
        "ln": Leaf((D,), (None,), dt, init="ones"),
        "wq": Leaf((D, H * hd), ("fsdp", "tp"), dt),
        "wk": Leaf((d_ctx, Hkv * hd), ("fsdp", "tp"), dt),
        "wv": Leaf((d_ctx, Hkv * hd), ("fsdp", "tp"), dt),
        "wo": Leaf((H * hd, D), ("tp", "fsdp"), dt),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = Leaf((H * hd,), ("tp",), dt, init="zeros")
        defs["bk"] = Leaf((Hkv * hd,), ("tp",), dt, init="zeros")
        defs["bv"] = Leaf((Hkv * hd,), ("tp",), dt, init="zeros")
    if cfg.qk_norm and not cross:
        defs["qn"] = Leaf((hd,), (None,), dt, init="ones")
        defs["kn"] = Leaf((hd,), (None,), dt, init="ones")
    return defs


def ffn_defs(cfg: ArchConfig, gelu: bool = False) -> Dict[str, Leaf]:
    D, F, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    defs = {
        "ln": Leaf((D,), (None,), dt, init="ones"),
        "w_up": Leaf((D, F), ("fsdp", "tp"), dt),
        "w_down": Leaf((F, D), ("tp", "fsdp"), dt),
    }
    if not gelu:  # SwiGLU
        defs["w_gate"] = Leaf((D, F), ("fsdp", "tp"), dt)
    return defs


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _project_qkv(p, h, src, cfg: ArchConfig, cross: bool):
    """Project to q [B,S,H,hd], k/v [B,Sk,Hkv,hd]; apply qk-norm + biases."""
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = h @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias and not cross:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(*h.shape[:-1], H, hd)
    k = k.reshape(*src.shape[:-1], Hkv, hd)
    v = v.reshape(*src.shape[:-1], Hkv, hd)
    if cfg.qk_norm and not cross:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    return q, k, v


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool, ctx: Optional[ShardingCtx] = None,
                        q_chunk: int = 512) -> jax.Array:
    """Chunked softmax attention. q [B,S,H,hd]; k/v [B,Sk,H,hd] (heads already
    repeated). Scores are materialized only per q-chunk (f32).

    Sharding constraints are applied INSIDE the scan body — without them the
    GSPMD partitioner is free to replicate the batch dim of the per-chunk
    score tensor, which blows per-chip HBM traffic up ~dp-fold (observed in
    the dry-run before this constraint existed)."""
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1  # largest divisor <= q_chunk
    nc = S // qc
    qs = q.reshape(B, nc, qc, H, hd).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(Sk)
    cs = (lambda a, *ax: ctx.cs(a, *ax)) if ctx is not None else (lambda a, *ax: a)

    def step(_, inp):
        idx, qb = inp  # qb [B,qc,H,hd]
        qb = cs(qb, "batch", None, "tp", None)
        # dot in io dtype (MXU accumulates f32 internally); softmax math in
        # f32. Using preferred_element_type=f32 here would make the backward
        # cotangent chain flow in f32, doubling bwd HBM + collective traffic.
        scores = jnp.einsum("bqhd,bkhd->bhqk", qb, k).astype(jnp.float32) * scale
        scores = cs(scores, "batch", "tp", None, None)
        if causal:
            qpos = idx * qc + jnp.arange(qc)
            mask = qpos[:, None] >= kpos[None, :]
            scores = jnp.where(mask[None, None], scores, -jnp.inf)
        m = jnp.max(scores, axis=-1, keepdims=True)
        m = jnp.maximum(m, -1e30)  # fully-masked rows
        p_ = jnp.exp(scores - m)
        l = jnp.sum(p_, axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bqhd", (p_ / l).astype(v.dtype), v)
        return None, cs(o, "batch", None, "tp", None)

    # flash-attention backward semantics: recompute the per-chunk score matrix
    # in the backward pass instead of stacking [nc,B,H,qc,Sk] probabilities in
    # HBM across the scan (the stacked residuals are the full S^2 matrix)
    _, outs = flags.scan(jax.checkpoint(step), None, (jnp.arange(nc), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def kv_blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool, ctx: Optional[ShardingCtx] = None,
                           kv_chunk: int = 512) -> jax.Array:
    """Flash attention chunked over the KV axis (context-parallel form).

    q [B,S,H,hd] stays (batch x seq)-sharded; k/v [B,Sk,Hkv,hd] are consumed
    in chunks with online softmax, so every chip's query shard attends to the
    full context without the score matrix ever exceeding [.., S_loc, kc].
    Grouped-GQA einsum — K/V are never head-repeated. Used by the "fsdp_cp"
    sharding mode where heads are NOT sharded (works for any head count,
    e.g. llama4-scout's 40 heads that 16-way TP cannot divide).
    """
    B, S, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = hd ** -0.5
    kc = min(kv_chunk, Sk)
    while Sk % kc:
        kc -= 1
    nk = Sk // kc
    cs = (lambda a, *ax: ctx.cs(a, *ax)) if ctx is not None else (lambda a, *ax: a)
    qg = q.reshape(B, S, Hkv, g, hd)
    qpos = jnp.arange(S)

    def step(carry, j):
        m_prev, l_prev, acc = carry
        kb = lax.dynamic_slice_in_dim(k, j * kc, kc, axis=1)
        vb = lax.dynamic_slice_in_dim(v, j * kc, kc, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb).astype(jnp.float32) * scale
        s = cs(s, "batch", None, None, "sp", None)
        if causal:
            kpos = j * kc + jnp.arange(kc)
            s = jnp.where((qpos[:, None] >= kpos[None, :])[None, None, None],
                          s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_new = jnp.maximum(m_new, -1e30)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
        acc = acc * alpha.astype(acc.dtype) + pv.astype(acc.dtype)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, g, S, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, S, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, S, hd), jnp.float32)
    (m, l, acc), _ = flags.scan(jax.checkpoint(step), (m0, l0, a0),
                                jnp.arange(nk))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


def attn_full(p, x, cfg: ArchConfig, ctx: ShardingCtx,
              positions: jax.Array, kv_src: Optional[jax.Array] = None,
              causal: bool = True, use_rope: bool = True,
              want_cache: bool = False,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full-sequence self/cross attention.

    Returns (output [B,S,D], cache entries {k,v: [B,Sk,Hkv,hd]} if asked).
    The cache re-sharding constraint (sequence over "model") is only applied
    when a cache is requested — in training it would fight the head sharding
    and trigger involuntary full rematerialization in GSPMD.
    """
    cross = kv_src is not None
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if ctx.mode == "tp_sp_opt" and x.ndim == 3 and x.shape[1] > 1:
        # Megatron-SP: gather the seq-sharded residual to full-seq exactly
        # once, on the bf16 NORM OUTPUT. Without this explicit boundary the
        # partitioner gathers the f32 norm internals once per consumer (3x
        # the bytes, 2x the dtype width) — measured 14.5GB/layer vs the
        # theoretical 2.4GB/layer of TP+SP (EXPERIMENTS.md §Perf it5).
        h = ctx.cs(h, "batch", None, None)
    src = kv_src if cross else h
    q, k, v = _project_qkv(p, h, src, cfg, cross)
    if use_rope and not cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    cache = None
    if want_cache:
        cache = {"k": ctx.cs(k, "batch", "kv_sp", None, None),
                 "v": ctx.cs(v, "batch", "kv_sp", None, None)}
    if ctx.mode == "fsdp_cp":
        # context-parallel: q stays (batch x seq)-sharded, K/V gathered to
        # full-seq per chip (GQA keeps them small), flash over KV chunks
        q = ctx.cs(q, "batch", "sp", None, None)
        k = ctx.cs(k, "batch", None, None, None)
        v = ctx.cs(v, "batch", None, None, None)
        o = kv_blockwise_attention(q, k, v, causal=causal and not cross,
                                   ctx=ctx)
    else:
        # Megatron TP: repeat KV to H heads; shard over heads where divisible
        if Hkv != H:
            k = jnp.repeat(k, H // Hkv, axis=2)
            v = jnp.repeat(v, H // Hkv, axis=2)
        q = ctx.cs(q, "batch", None, "tp", None)
        k = ctx.cs(k, "batch", None, "tp", None)
        v = ctx.cs(v, "batch", None, "tp", None)
        o = blockwise_attention(q, k, v, causal=causal and not cross, ctx=ctx)
    o = o.reshape(*x.shape[:-1], H * cfg.head_dim_)
    out = o @ p["wo"]
    return ctx.cs(out, "batch", "sp", None), cache


def update_kv_cache(cache_k, cache_v, k_new, v_new, positions):
    """Write one token's K/V at per-sequence positions.
    cache [B,Smax,Hkv,hd]; new [B,1,Hkv,hd]; positions [B]."""
    def upd(c, n, pos):
        # c [Smax,Hkv,hd]; n [1,Hkv,hd]; pos scalar
        return lax.dynamic_update_slice(c, n, (pos, 0, 0))
    ck = jax.vmap(upd)(cache_k, k_new, positions)
    cv = jax.vmap(upd)(cache_v, v_new, positions)
    return ck, cv


def attn_decode(p, x, cache: Dict[str, jax.Array], cfg: ArchConfig,
                ctx: ShardingCtx, positions: jax.Array,
                cross: bool = False, use_rope: bool = True,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode attention against a (sharded) KV cache.

    x [B,1,D]; cache {k,v: [B,Smax,Hkv,hd]}; positions [B] = index of the new
    token. Cross-attention reads a static cache (no write, no masking by pos).
    Grouped-GQA einsum: the cache is never head-repeated.
    """
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = H // Hkv
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if ctx.mode == "fsdp_cp":
        # weight-stationary decode projections (see ffn_apply)
        h = ctx.cs(h, None, None, "fsdp")
    if cross:
        ck, cv = cache["k"], cache["v"]
        q = (h @ p["wq"]).reshape(*h.shape[:-1], H, hd)
        if ctx.mode == "fsdp_cp":
            q = ctx.cs(q, "batch", None, None, None)  # back to batch-sharded
        new_cache = cache
    else:
        q, k_new, v_new = _project_qkv(p, h, h, cfg, cross=False)
        if ctx.mode == "fsdp_cp":
            q = ctx.cs(q, "batch", None, None, None)
            k_new = ctx.cs(k_new, "batch", None, None, None)
            v_new = ctx.cs(v_new, "batch", None, None, None)
        if use_rope:
            q = apply_rope(q, positions[:, None], cfg.rope_theta)
            k_new = apply_rope(k_new, positions[:, None], cfg.rope_theta)
        ck, cv = update_kv_cache(cache["k"], cache["v"], k_new, v_new, positions)
        ck = ctx.cs(ck, "batch", "kv_sp", None, None)
        cv = ctx.cs(cv, "batch", "kv_sp", None, None)
        new_cache = {"k": ck, "v": cv}
    B, Smax = ck.shape[0], ck.shape[1]
    qg = q.reshape(B, 1, Hkv, g, hd)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, ck,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    if not cross:
        valid = jnp.arange(Smax)[None, :] <= positions[:, None]  # [B,Smax]
        scores = jnp.where(valid[:, None, None, None, :], scores, -jnp.inf)
    m = jnp.maximum(jnp.max(scores, axis=-1, keepdims=True), -1e30)
    pr = jnp.exp(scores - m)
    l = jnp.sum(pr, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bqhgd", (pr / l).astype(cv.dtype), cv)
    o = o.reshape(B, 1, H * hd)
    if ctx.mode == "fsdp_cp":
        o = ctx.cs(o, None, None, "tp")   # weight-stationary o-projection
        out = o @ p["wo"]
        return ctx.cs(out, None, None, "fsdp"), new_cache
    out = o @ p["wo"]
    return ctx.cs(out, "batch", None, None), new_cache


def attn_decode_paged(p, x, cfg: ArchConfig, ctx: ShardingCtx,
                      positions: jax.Array, k_pages: jax.Array,
                      v_pages: jax.Array, layer, block_table: jax.Array,
                      seq_lens: jax.Array, rows: jax.Array, offs: jax.Array,
                      attend, inline: bool = False
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode attention against the PAGED KV arena.

    x [B,1,D]; k/v_pages [L, n_rows, page, Hkv, hd] — the node arena plane,
    which may hold more layers/rows than this model uses; ``layer`` the
    model's stacked layer index into the plane; block_table [B, W] plane-row
    indices; rows/offs [B] the write coordinate of the new token. The new
    token's (roped) K/V is scattered into its page before attention, then
    ``attend`` (the Pallas paged kernel on TPU, the jnp reference elsewhere —
    chosen once at engine construction) reads through the block table.
    Returns (output [B,1,D], k_pages, v_pages).

    ``inline=True`` (the decode-horizon hot loop) hands the new token's K/V
    to ``attend`` directly (``k_new``/``v_new`` splice, see
    ``kernels.paged_attention``) so the attention read no longer depends on
    the full-plane scatter; the scatter still runs — later horizon
    iterations read the token from its page — but off the critical path.
    Outputs are bitwise identical to the ``inline=False`` path for every
    live lane.
    """
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim_
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k_new, v_new = _project_qkv(p, h, h, cfg, cross=False)
    q = apply_rope(q, positions[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, positions[:, None], cfg.rope_theta)
    if inline:
        kp = lax.dynamic_index_in_dim(k_pages, layer, 0, keepdims=False)
        vp = lax.dynamic_index_in_dim(v_pages, layer, 0, keepdims=False)
        o = attend(q[:, 0], kp, vp, block_table, seq_lens,
                   k_new=k_new[:, 0].astype(k_pages.dtype),
                   v_new=v_new[:, 0].astype(v_pages.dtype))
        k_pages = k_pages.at[layer, rows, offs].set(
            k_new[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[layer, rows, offs].set(
            v_new[:, 0].astype(v_pages.dtype))
    else:
        k_pages = k_pages.at[layer, rows, offs].set(
            k_new[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[layer, rows, offs].set(
            v_new[:, 0].astype(v_pages.dtype))
        kp = lax.dynamic_index_in_dim(k_pages, layer, 0, keepdims=False)
        vp = lax.dynamic_index_in_dim(v_pages, layer, 0, keepdims=False)
        o = attend(q[:, 0], kp, vp, block_table, seq_lens)   # [B, H, hd]
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    return ctx.cs(o @ p["wo"], "batch", None, None), k_pages, v_pages


def attn_suffix(p, x, cfg: ArchConfig, ctx: ShardingCtx,
                positions: jax.Array, pk: jax.Array, pv: jax.Array,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill only the suffix of a prompt against cached prefix KV.

    x [B,S,D] the unmatched suffix tokens; positions [B,S] their absolute
    positions (prefix length + arange); pk/pv [P,Hkv,hd] the prefix KV
    gathered from arena rows (already roped at absolute positions when the
    prefix itself was prefilled). Deliberately mirrors the exact per-row
    arithmetic of :func:`blockwise_attention` (io-dtype score einsum with
    head-repeated K/V, f32 softmax, ``maximum(m, -1e30)``) so that decode
    outputs with the prefix cache on are bitwise identical to a full
    prefill. Returns (output [B,S,D], k_new, v_new [B,S,Hkv,hd]).
    """
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k_new, v_new = _project_qkv(p, h, h, cfg, cross=False)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    B = x.shape[0]
    pkb = jnp.broadcast_to(pk[None].astype(k_new.dtype), (B,) + pk.shape)
    pvb = jnp.broadcast_to(pv[None].astype(v_new.dtype), (B,) + pv.shape)
    k = jnp.concatenate([pkb, k_new], axis=1)
    v = jnp.concatenate([pvb, v_new], axis=1)
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    mask = positions[:, :, None] >= kpos[None, None, :]
    scores = jnp.where(mask[:, None], scores, -jnp.inf)
    m = jnp.maximum(jnp.max(scores, axis=-1, keepdims=True), -1e30)
    p_ = jnp.exp(scores - m)
    l = jnp.sum(p_, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", (p_ / l).astype(v.dtype), v)
    out = o.reshape(B, x.shape[1], H * hd) @ p["wo"]
    return ctx.cs(out, "batch", "sp", None), k_new, v_new


def attn_chunk_paged(p, x, cfg: ArchConfig, ctx: ShardingCtx,
                     positions: jax.Array, k_pages: jax.Array,
                     v_pages: jax.Array, layer, block_table: jax.Array,
                     rows: jax.Array, offs: jax.Array, attend
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill attention against the PAGED KV arena.

    x [B,C,D] one fixed-width chunk of prompt tokens per sequence;
    positions [B,C] their absolute positions (pad rows repeat position 0);
    k/v_pages the node arena plane; ``layer`` the model's stacked layer
    index into the plane; block_table [B,W] plane-row indices; rows/offs
    [B,C] the write coordinates of the chunk's tokens (pad columns point at
    the null row). The chunk's (roped) K/V is scattered into its pages
    before attention, then ``attend`` (the Pallas chunk kernel on TPU, the
    jnp reference elsewhere) reads earlier chunks AND this chunk through
    the block table under a causal mask on absolute positions. Returns
    (output [B,C,D], k_pages, v_pages).
    """
    B, C = x.shape[0], x.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim_
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k_new, v_new = _project_qkv(p, h, h, cfg, cross=False)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    k_pages = k_pages.at[layer, rows, offs].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[layer, rows, offs].set(v_new.astype(v_pages.dtype))
    kp = lax.dynamic_index_in_dim(k_pages, layer, 0, keepdims=False)
    vp = lax.dynamic_index_in_dim(v_pages, layer, 0, keepdims=False)
    o = attend(q, kp, vp, block_table, positions)            # [B, C, H, hd]
    o = o.reshape(B, C, H * hd).astype(x.dtype)
    return ctx.cs(o @ p["wo"], "batch", "sp", None), k_pages, v_pages


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_apply(p, x, cfg: ArchConfig, ctx: ShardingCtx, gelu: bool = False):
    decode = x.ndim == 3 and x.shape[1] == 1
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if ctx.mode == "tp_sp_opt" and x.ndim == 3 and not decode:
        h = ctx.cs(h, "batch", None, None)   # single bf16 seq-gather
    if ctx.mode == "fsdp_cp" and decode:
        # WEIGHT-STATIONARY decode: activations are tiny [B,1,D]; re-shard
        # them to match the 2D weight sharding (D over data, F over model)
        # so every matmul contracts locally against the chip's own weight
        # shard + a small activation psum — instead of all-gathering
        # ~weights/tp bytes of parameters per layer per TOKEN.
        h = ctx.cs(h, None, None, "fsdp")
    up = h @ p["w_up"]
    if gelu:
        act = jax.nn.gelu(up)
    else:
        act = jax.nn.silu(h @ p["w_gate"]) * up
    if ctx.mode == "fsdp_cp":
        if decode:
            act = ctx.cs(act, None, None, "tp")
        else:
            # tokens stay (batch x seq)-sharded; weights gathered per layer
            act = ctx.cs(act, "batch", "sp", None)
    else:
        act = ctx.cs(act, "batch", None, "tp")
    out = act @ p["w_down"]
    if ctx.mode == "fsdp_cp" and decode:
        # keep the decode residual D-sharded over data (weight-stationary
        # end-to-end): re-sharding the [B,1,D] residual costs ~2MB/layer vs
        # all-gathering w_down (~100MB f32/layer) to produce a full-D output
        return ctx.cs(out, None, None, "fsdp")
    return ctx.cs(out, "batch", "sp", None)
