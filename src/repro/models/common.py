"""Common model machinery: parameter definitions, norms, RoPE.

Parameters are declared as trees of ``Leaf`` records carrying shape, dtype,
init style and *logical* sharding axes. Three materializers walk the same tree:

  * ``init_tree``     -> real jnp arrays (smoke tests / examples)
  * ``abstract_tree`` -> jax.ShapeDtypeStruct stand-ins (dry-run; no allocation)
  * ``pspec_tree``    -> jax.sharding.PartitionSpec per leaf (pjit in/out specs)

Logical axes vocabulary (resolved by repro.distributed.sharding):
  "fsdp"  — parameter sharding over the data(+pod) axes (ZeRO-3 style)
  "tp"    — tensor parallel over the model axis
  "exp"   — expert parallel over the model axis (MoE expert dim)
  "stack" — scan-stacked layer-group dim (never sharded)
  None    — replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Leaf:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"      # normal | zeros | ones
    scale: float = 1.0        # stddev multiplier for normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def init_tree(defs, key, dtype_override=None):
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        dt = dtype_override or leaf.dtype
        if leaf.init == "zeros":
            arr = jnp.zeros(leaf.shape, dt)
        elif leaf.init == "ones":
            arr = jnp.ones(leaf.shape, dt)
        else:
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            std = leaf.scale / math.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(k, leaf.shape, jnp.float32) * std).astype(dt)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_tree(defs, dtype_override=None):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype_override or l.dtype),
        defs, is_leaf=_is_leaf)


def pspec_tree(defs, rules: Dict[Optional[str], Any]):
    from jax.sharding import PartitionSpec as P

    def to_spec(l: Leaf):
        return P(*[rules.get(a, None) for a in l.axes])

    return jax.tree_util.tree_map(to_spec, defs, is_leaf=_is_leaf)


def tree_bytes(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_leaf)
    total = 0
    for l in leaves:
        total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_leaf)
    return sum(int(np.prod(l.shape)) for l in leaves)


# ---------------------------------------------------------------------------
# Numeric helpers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """[head_dim/2] inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., seq, heads, head_dim]; positions broadcastable to [..., seq]."""
    dt = x.dtype
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                      # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]                          # [..., seq, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(dt)


def pad_vocab(vocab: int, multiple: int = 512) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def with_sharding(x, spec):
    """Sharding constraint that is a no-op outside a mesh context."""
    from jax.sharding import PartitionSpec
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec) \
            if isinstance(spec, PartitionSpec) else x
    except (ValueError, RuntimeError):
        return x
