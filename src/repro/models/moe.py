"""Mixture-of-Experts FFN with sort-based token dispatch and expert parallelism.

Two execution paths sharing one dispatch core:

  * ``_moe_local``   — no mesh (smoke tests): plain capacity-bucketed dispatch.
  * ``_moe_sharded`` — shard_map over the full mesh: tokens live on their
    (data x model) shard, routing + capacity bucketing are LOCAL, experts are
    sharded over the model axis (EP) and tokens move via two all_to_alls
    (DeepSeek-style dispatch/combine). Expert weights are FSDP-sharded over the
    data axes and all-gathered inside (ZeRO-3); shard_map transposes the gather
    to a psum_scatter in backward automatically.

Dispatch is scatter-free: pairs are argsorted by expert and both dispatch and
combine are pure gathers (scatters shard poorly under GSPMD and we must keep
the lowered HLO collective-clean for the roofline).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.distributed.sharding import ShardingCtx
from repro.models.common import Leaf, rms_norm


def moe_defs(cfg: ArchConfig) -> Dict[str, Leaf]:
    m = cfg.moe
    D, E, F, dt = cfg.d_model, m.n_experts, m.d_ff_expert, cfg.dtype
    return {
        "ln": Leaf((D,), (None,), dt, init="ones"),
        "router": Leaf((D, E), ("fsdp", None), dt),
        "w_gate": Leaf((E, D, F), ("exp", "fsdp", None), dt),
        "w_up": Leaf((E, D, F), ("exp", "fsdp", None), dt),
        "w_down": Leaf((E, F, D), ("exp", None, "fsdp"), dt),
    }


def _capacity(n_tokens: int, n_experts: int, top_k: int, cf: float,
              dropless: bool = False) -> int:
    if dropless:
        # capacity that can never drop (all pairs routed to one expert);
        # used for decode where n_tokens is tiny and drops corrupt outputs
        return n_tokens * top_k
    return max(1, math.ceil(n_tokens * top_k * cf / n_experts))


def _route_and_bucket(xt, router, E: int, K: int, C: int):
    """Local routing: top-k experts per token + capacity bucketing.

    xt [T, D]. Returns (buf [E, C, D], combine info).
    Scatter-free: double-argsort gives each (token, k) pair its rank within its
    expert; dispatch and combine are gathers.
    """
    T = xt.shape[0]
    logits = (xt @ router).astype(jnp.float32)            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gvals, gidx = lax.top_k(probs, K)                     # [T, K]
    eflat = gidx.reshape(-1)                              # [T*K]
    order = jnp.argsort(eflat)                            # stable
    se = eflat[order]
    counts = jnp.sum(jax.nn.one_hot(eflat, E, dtype=jnp.int32), axis=0)  # [E]
    start = jnp.cumsum(counts) - counts                   # exclusive prefix
    rank_sorted = jnp.arange(T * K, dtype=jnp.int32) - start[se]
    inv = jnp.argsort(order)
    rank = rank_sorted[inv]                               # [T*K] rank within expert
    keep = rank < C
    # dispatch (gather): buf[e, c] = token of the pair at sorted slot start[e]+c
    grid_c = jnp.arange(C, dtype=jnp.int32)
    gslot = start[:, None] + grid_c[None, :]              # [E, C]
    valid = grid_c[None, :] < jnp.minimum(counts, C)[:, None]
    pair_tok_sorted = (order // K).astype(jnp.int32)      # token id per sorted pair
    tok_idx = jnp.take(pair_tok_sorted, jnp.clip(gslot, 0, T * K - 1), axis=0)
    buf = jnp.where(valid[..., None], jnp.take(xt, tok_idx, axis=0), 0)
    info = (eflat, rank, keep, gvals.astype(xt.dtype))
    return buf, info


def _combine(out_buf, info, T: int, K: int, C: int):
    """out_buf [E, C, D] -> y [T, D] (gather + gate-weighted sum over K)."""
    eflat, rank, keep, gvals = info
    flat = out_buf.reshape(-1, out_buf.shape[-1])         # [E*C, D]
    slot = eflat * C + jnp.clip(rank, 0, C - 1)
    vals = jnp.take(flat, slot, axis=0)                   # [T*K, D]
    vals = jnp.where(keep[:, None], vals, 0)
    vals = vals.reshape(T, K, -1) * gvals[..., None]
    return jnp.sum(vals, axis=1)


def _expert_ffn(buf, wg, wu, wd):
    """buf [E?, C, D]; weights [E?, D, F] / [E?, F, D]."""
    a = jnp.einsum("ecd,edf->ecf", buf, wg)
    b = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(a) * b
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _moe_local(p, x, moe: MoEConfig):
    B, S, D = x.shape
    T = B * S
    E, K = moe.n_experts, moe.top_k
    C = _capacity(T, E, K, moe.capacity_factor, dropless=(S == 1))
    xt = x.reshape(T, D)
    buf, info = _route_and_bucket(xt, p["router"], E, K, C)
    out_buf = _expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
    return _combine(out_buf, info, T, K, C).reshape(B, S, D)


def _moe_sharded(p, x, moe: MoEConfig, ctx: ShardingCtx):
    """shard_map EP over the model axis; tokens local to (dp x tp) shards."""
    mesh = ctx.mesh
    dp_axes = ctx.batch_axes          # ("data",) or ("pod","data")
    tp = "model"
    dp_size = ctx.axis_size("batch")
    tp_size = mesh.shape[tp]
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k
    # token sharding: batch over dp, seq over tp (where divisible)
    seq_shardable = S % tp_size == 0
    b_loc = B // dp_size if B % dp_size == 0 else B
    s_loc = S // tp_size if seq_shardable else S
    T_loc = b_loc * s_loc
    C_loc = _capacity(T_loc, E, K, moe.capacity_factor, dropless=(S == 1))
    E_loc = E // tp_size

    x_spec = P(dp_axes if B % dp_size == 0 else None,
               tp if seq_shardable else None, None)
    specs_p = {
        "ln": P(None),
        "router": P(dp_axes, None),
        "w_gate": P(tp, dp_axes, None),
        "w_up": P(tp, dp_axes, None),
        "w_down": P(tp, None, dp_axes),
    }

    def body(pb, xb):
        # xb [b_loc, s_loc, D] local tokens
        xt = xb.reshape(T_loc, D)
        router = lax.all_gather(pb["router"], dp_axes, axis=0, tiled=True)
        buf, info = _route_and_bucket(xt, router, E, K, C_loc)   # [E, C_loc, D]
        # dispatch: regroup experts onto their model shard
        buf = lax.all_to_all(buf, tp, split_axis=0, concat_axis=1, tiled=True)
        wg = lax.all_gather(pb["w_gate"], dp_axes, axis=1, tiled=True)
        wu = lax.all_gather(pb["w_up"], dp_axes, axis=1, tiled=True)
        wd = lax.all_gather(pb["w_down"], dp_axes, axis=2, tiled=True)
        out = _expert_ffn(buf, wg, wu, wd)                       # [E_loc, C_loc*tp, D]
        out = lax.all_to_all(out, tp, split_axis=1, concat_axis=0, tiled=True)
        y = _combine(out, info, T_loc, K, C_loc)
        return y.reshape(b_loc, s_loc, D)

    fn = jax.shard_map(body, mesh=mesh, in_specs=(specs_p, x_spec),
                       out_specs=x_spec, check_vma=False)
    pb = {k: p[k] for k in specs_p}
    return fn(pb, x)


def moe_apply(p, x, cfg: ArchConfig, ctx: ShardingCtx):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if ctx.enabled:
        out = _moe_sharded(p, h, cfg.moe, ctx)
    else:
        out = _moe_local(p, h, cfg.moe)
    return ctx.cs(out, "batch", "sp", None)
