"""Trace-time flags + the scan wrapper used by every model loop.

``ANALYSIS_UNROLL`` exists because XLA's ``cost_analysis()`` counts a while
loop body ONCE, not times its trip count. The dry-run therefore lowers small
fully-unrolled model variants (1 and 2 layer-groups) and extrapolates the
per-group cost linearly — see repro.launch.dryrun. Production lowering keeps
rolled scans (compile time flat in depth; remat at group boundaries).
"""
from __future__ import annotations

from jax import lax

ANALYSIS_UNROLL = False


def scan(body, init, xs, length=None):
    import repro.models.flags as F
    return lax.scan(body, init, xs, length=length,
                    unroll=True if F.ANALYSIS_UNROLL else 1)
