"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state — the dry-run must set XLA_FLAGS before any
device initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    if len(jax.devices()) < n:
        raise ValueError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh((data, model), ("data", "model"))
