import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (architecture x input-shape)
cell on the production meshes, record memory/cost/collective analysis.

This proves the distribution config is coherent without real hardware:
  * 16x16 single-pod mesh (256 chips)  — roofline baseline table
  * 2x16x16 multi-pod mesh (512 chips) — proves the "pod" axis shards

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
                                               [--skip-existing]

Results land in results/dryrun/<mesh>/<arch>__<shape>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES, get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (HBM_CAP, parse_collectives, roofline_terms)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COST_KEYS = ("flops", "bytes accessed", "transcendentals", "ideal_bytes")


def _depth_variant(cfg, k: int):
    """Same arch with k layer-groups (and k encoder layers) — used for the
    unrolled two-point cost extrapolation."""
    changes = {"n_layers": k * cfg.layer_pattern_period}
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(cfg.encoder, n_layers=k)
    return dataclasses.replace(cfg, **changes)


def _get_cost(compiled, hlo_text=None):
    from repro.launch.roofline import ideal_bytes
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    out = {k: float(cost.get(k, 0.0)) for k in _COST_KEYS if k in cost}
    out["ideal_bytes"] = ideal_bytes(hlo_text if hlo_text is not None
                                     else compiled.as_text())
    return out


def analysis_extrapolate(cfg, shape_name: str, mesh, mode="tp_sp") -> dict:
    """XLA's cost_analysis counts a while-loop body once, not x trip-count, so
    rolled scans under-report. We lower fully-UNROLLED 1-group and 2-group
    variants and extrapolate linearly to the real depth:

        cost(G) = cost(1) + (G - 1) * (cost(2) - cost(1))

    (embedding / loss / optimizer costs land in the fixed part; per-group
    compute, bytes and collectives in the slope). Collectives are extrapolated
    per op-kind the same way.
    """
    from repro.models import build_model, flags
    from repro.training.train_step import lower_cell

    costs, colls = [], []
    for k in (1, 2):
        model = build_model(_depth_variant(cfg, k), mesh=mesh, mode=mode)
        flags.ANALYSIS_UNROLL = True
        try:
            with mesh:
                compiled = lower_cell(model, shape_name).compile()
        finally:
            flags.ANALYSIS_UNROLL = False
        text = compiled.as_text()
        costs.append(_get_cost(compiled, text))
        colls.append(parse_collectives(text))
    G = cfg.n_layers // cfg.layer_pattern_period
    cost = {k: costs[0][k] + (G - 1) * max(0.0, costs[1][k] - costs[0][k])
            for k in _COST_KEYS}
    coll = {}
    kinds = set(colls[0]) | set(colls[1])
    zero = {"count": 0, "bytes": 0.0, "traffic": 0.0, "max_group": 0}
    for kind in kinds:
        c1 = colls[0].get(kind, zero)
        c2 = colls[1].get(kind, zero)
        coll[kind] = {
            f: c1[f] + (G - 1) * max(0.0, c2[f] - c1[f])
            for f in ("count", "bytes", "traffic")
        }
        coll[kind]["max_group"] = max(c1["max_group"], c2["max_group"])
    return {"cost": cost, "collectives": coll,
            "cost_points": costs, "collective_points": colls}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None, mode: str = "tp_sp") -> dict:
    """Lower + compile one cell; return the analysis record."""
    from repro.models import build_model
    from repro.training.train_step import lower_cell

    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mode": mode,
           "mesh": "multi" if multi_pod else "single"}
    if shape_name in cfg.skipped_shapes():
        rec["status"] = "skipped"
        rec["skip_reason"] = cfg.skipped_shapes()[shape_name]
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    model = build_model(cfg, mesh=mesh, mode=mode)
    if overrides:
        for k, v in overrides.items():
            setattr(model, k, v)
    t0 = time.time()
    with mesh:
        lowered = lower_cell(model, shape_name)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        }
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec["memory"]["peak_bytes_per_device"] = int(peak)
        rec["memory"]["fits_v5e_16g"] = bool(peak <= HBM_CAP)
    except Exception as e:  # pragma: no cover - backend capability varies
        rec["memory"] = {"error": str(e)}

    rec["cost_scanned"] = _get_cost(compiled)
    rec["collectives_scanned"] = parse_collectives(compiled.as_text())
    # accurate per-step cost: unrolled 2-point depth extrapolation
    extra = analysis_extrapolate(cfg, shape_name, mesh, mode=mode)
    rec["cost"] = extra["cost"]
    rec["collectives"] = extra["collectives"]
    rec["cost_points"] = extra["cost_points"]
    rec["collective_points"] = extra["collective_points"]
    rec["roofline"] = roofline_terms(rec["cost"], rec["collectives"], n_chips,
                                     cfg, SHAPES[shape_name])
    rec["status"] = "ok"
    return rec


def cell_path(arch: str, shape: str, mesh: str, mode: str = "tp_sp") -> Path:
    d = mesh if mode == "tp_sp" else f"{mesh}-{mode}"
    return RESULTS / d / f"{arch}__{shape}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--sharding-mode", default="tp_sp",
                    choices=["tp_sp", "tp_sp_opt", "fsdp_cp"])
    args = ap.parse_args()

    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                out = cell_path(arch, shape, mesh_name, args.sharding_mode)
                if args.skip_existing and out.exists():
                    print(f"[skip-existing] {mesh_name}/{arch}/{shape}")
                    continue
                print(f"[dryrun] mesh={mesh_name} arch={arch} shape={shape} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape,
                                   multi_pod=(mesh_name == "multi"),
                                   mode=args.sharding_mode)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()}
                    failures.append((mesh_name, arch, shape, str(e)))
                out.parent.mkdir(parents=True, exist_ok=True)
                out.write_text(json.dumps(rec, indent=1))
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    mem = rec.get("memory", {})
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"peak={mem.get('peak_bytes_per_device', 0)/1e9:.2f}GB "
                          f"compute={r['compute_s']*1e3:.2f}ms "
                          f"memory={r['memory_s']*1e3:.2f}ms "
                          f"coll={r['collective_s']*1e3:.2f}ms "
                          f"bound={r['bottleneck']}", flush=True)
                elif rec["status"] == "skipped":
                    print(f"  skipped: {rec['skip_reason']}")
                else:
                    print(f"  ERROR: {rec['error'][:500]}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nAll requested cells passed.")


if __name__ == "__main__":
    main()
