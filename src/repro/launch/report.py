"""Render the EXPERIMENTS.md roofline tables from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--mode tp_sp|fsdp_cp] [--mesh single|multi]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def table(mesh: str = "single", mode: str = "tp_sp") -> str:
    d = RESULTS / (mesh if mode == "tp_sp" else f"{mesh}-{mode}")
    lines = [
        "| arch | shape | bound | compute (ms) | memory (ms) | collective "
        "(ms) | peak GB/chip | fits v5e | useful-FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | — | skipped (sub-quadratic contract) |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['bottleneck']} "
            f"| {rf['compute_s']*1e3:.1f} | {rf['memory_s']*1e3:.1f} "
            f"| {rf['collective_s']*1e3:.1f} "
            f"| {mem.get('peak_bytes_per_device', 0)/1e9:.2f} "
            f"| {'yes' if mem.get('fits_v5e_16g') else 'NO'} "
            f"| {rf.get('useful_flop_ratio', 0):.2f} "
            f"| {rf.get('roofline_fraction', 0):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--mode", default="tp_sp")
    args = ap.parse_args()
    print(table(args.mesh, args.mode))


if __name__ == "__main__":
    main()
