"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = HLO_FLOPs_per_chip   / PEAK_FLOPS
    memory     = HLO_bytes_per_chip   / HBM_BW
    collective = traffic_per_chip     / ICI_BW

``cost_analysis()`` on a post-SPMD compiled executable reports the per-device
program, so its flops/bytes are already per-chip. Collective traffic is NOT in
cost_analysis — we parse the optimized HLO and apply ring-algorithm byte
multipliers per op (documented next to each).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
HBM_CAP = 16e9             # v5e HBM capacity

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (\([^)]*\)|\S+) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute"
    r"|ragged-all-to-all)(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [G,N]<=[...] -> N ranks per group
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _traffic(kind: str, out_bytes: int, n: int) -> float:
    """Per-chip link bytes for ring algorithms.

    all-gather     : each chip receives (n-1)/n of the gathered output
    all-reduce     : ring AR moves 2*(n-1)/n of the buffer through each chip
    reduce-scatter : each chip receives its 1/n after (n-1)/n passes ~ out*(n-1)
                     (out is the per-chip scattered result; input = out*n)
    all-to-all     : (n-1)/n of the buffer leaves the chip
    collective-permute : the whole buffer crosses one link
    """
    if kind == "collective-permute":
        return float(out_bytes)      # whole buffer crosses one link
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-gather":
        return out_bytes * f
    if kind == "all-reduce":
        return 2 * out_bytes * f
    if kind == "reduce-scatter":
        return out_bytes * (n - 1)
    if kind in ("all-to-all", "ragged-all-to-all"):
        return out_bytes * f
    return 0.0


_COUNTED_OPS = {
    # ops whose operand+output bytes are genuine HBM traffic on TPU
    "dot", "convolution", "fusion", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "sort", "all-reduce", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute", "ragged-all-to-all",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_ANYOP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (\([^)]*\)|\S+?\]\S*|\S+) ([\w\-]+)\(")


def ideal_bytes(hlo_text: str) -> float:
    """Fusion-ideal per-chip HBM traffic from the optimized HLO.

    XLA:CPU leaves large elementwise/convert/copy chains unfused, so raw
    ``cost_analysis()['bytes accessed']`` wildly over-reports what a TPU (which
    fuses those chains) would move through HBM. This proxy assumes PERFECT
    elementwise fusion: only ops that must touch HBM on TPU are charged —
    matmuls/convolutions (operands + outputs), data-movement ops
    (gather/scatter/dynamic-slice/update), sorts, collectives, and op-level
    fusions (their internals are free, their operands/outputs are not).
    Ops inside fused computations are skipped (their traffic is the fusion
    op's operands/outputs). Elementwise, broadcast, reshape, convert, copy,
    reduce, parameter, constant are treated as fused/free.
    """
    total = 0.0
    in_fused = False
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line.strip()) if "{" in line else None
        if cm:
            name = cm.group(1)
            in_fused = name.startswith(("fused_", "region_", "wide."))
            continue
        if in_fused:
            continue
        m = _ANYOP_RE.match(line)
        if not m:
            continue
        op = m.group(2)
        if op not in _COUNTED_OPS:
            continue
        # output + all operand shapes on the line (operands printed inline)
        total += sum(_shape_bytes(s) for s in _split_op_shapes(line))
    return total


def _split_op_shapes(line: str) -> List[str]:
    """Output type + operand types of one HLO op line (drops attr noise)."""
    head, _, rest = line.partition(" = ")
    body = rest
    # cut trailing attributes that may contain shapes (e.g. metadata)
    for cut in (", sharding=", ", metadata=", ", backend_config=",
                ", calls=", ", kind="):
        idx = body.find(cut)
        if idx >= 0:
            body = body[:idx]
    return [body]


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum collective output bytes + modeled link traffic per op kind.

    ``-start`` ops counted once (their ``-done`` twin carries no new data).
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        if "-done" in line.split("=")[0] if "=" in line else False:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        n = _group_size(line)
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0, "traffic": 0.0,
                                  "max_group": 0})
        d["count"] += 1
        d["bytes"] += b
        d["traffic"] += _traffic(kind, b, n)
        d["max_group"] = max(d["max_group"], n)
    return out


def model_flops(cfg, shape: Dict[str, Any]) -> float:
    """Useful model FLOPs for the cell: 6*N_active*tokens (train),
    2*N_active*tokens (prefill), 2*N_active*new_tokens (decode)."""
    n_active = cfg.active_param_count()
    b, s = shape["global_batch"], shape["seq_len"]
    if shape["kind"] == "train":
        return 6.0 * n_active * b * s
    if shape["kind"] == "prefill":
        return 2.0 * n_active * b * s
    return 2.0 * n_active * b  # decode: one new token per sequence


def roofline_terms(cost: Dict[str, float], collectives: Dict[str, Dict],
                   n_chips: int, cfg=None, shape=None) -> Dict[str, Any]:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    # memory term: fusion-ideal traffic (see ideal_bytes); raw bytes-accessed
    # kept as the unfused upper bound diagnostic
    ideal = float(cost.get("ideal_bytes", bytes_acc))
    traffic = sum(d["traffic"] for d in collectives.values())
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": ideal / HBM_BW,
        "memory_s_unfused_bound": bytes_acc / HBM_BW,
        "collective_s": traffic / ICI_BW,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "ideal_bytes_per_chip": ideal,
        "collective_traffic_per_chip": traffic,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["step_s_lower_bound"] = total
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        terms["model_flops_global"] = mf
        global_hlo = flops * n_chips
        terms["useful_flop_ratio"] = mf / global_hlo if global_hlo else 0.0
        # roofline fraction: useful model flops vs what the chips could do in
        # the bound step time
        if total > 0:
            terms["roofline_fraction"] = mf / (n_chips * PEAK_FLOPS * total)
    return terms
