"""Trace-driven discrete-event simulator for cross-cluster LLM-MAS serving.

Event loop over (arrival, ready, start, finish); nodes are SimNode instances
(residency + accounting + coordination, simulated time). Execution duration
uses the TRUE output length through the same cost model the scheduler's
predictions use — so prediction error manifests as queueing/admission error
exactly as in the paper.

The simulator is one of the two :class:`~repro.core.sched.substrate.Substrate`
implementations (the other is the live ``ClusterGateway``): policies from the
unified registry (``repro.core.sched.policies``) drive it through the shared
priority / reservation / route / on_finish surface.

Boundary preemption semantics (§III.D): with ``requeue_at_boundary`` the
successor of a finished stage re-enters the global queue and contends under
the policy's order; without it, job continuity keeps the successor on the
same node ahead of the queue (run-to-completion), which is what lets long
batch workflows block interactive work (Table II).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.predictor.cost_model import (HardwareSpec, ModelProfile,
                                             synthetic_profile)
from repro.core.sched.policies import SchedPolicy, make_policy
from repro.core.sched.substrate import SchedStage
from repro.core.topology import DEFAULT_RTT, validate_rtt
from repro.data.apps import APPS, APP_ID, MODELS, MODEL_PARAMS_B
from repro.data.tracegen import JobRecord, StageRecord
from repro.sim.cluster import SimNode


@dataclasses.dataclass
class SimConfig:
    nodes_per_cluster: Tuple[int, ...] = (2, 2, 1)
    hbm: float = 40e9
    max_concurrency: int = 8
    reserve_len: int = 2048          # baseline (non-predictive) KV reservation
    interactive_wait_budget_s: float = 2.0
    slo_factor: float = 2.0
    preempt_gain_s: float = 1.0      # boundary-preemption hysteresis
    preempt_cooldown_s: float = 5.0
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    policy: str
    slo_attainment: float
    mean_latency_s: float
    interactive_queue_delay_s: float
    p95_latency_s: float
    finished_jobs: int
    cold_starts: int
    preemptions: int

    def row(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def default_profiles(hw: Optional[HardwareSpec] = None) -> Dict[str, ModelProfile]:
    hw = hw or HardwareSpec(name="a100-40g", peak_flops=312e12, hbm_bw=1555e9,
                            hbm_capacity=40e9, host_link_bw=25e9)
    return {name: synthetic_profile(name, b, hw)
            for name, b in zip(MODELS, MODEL_PARAMS_B)}


class Simulator:
    """The SIM-plane Substrate: simulated time, true-length execution."""

    def __init__(self, jobs: Sequence[JobRecord],
                 policy: Union[SchedPolicy, str],
                 cfg: Optional[SimConfig] = None,
                 profiles: Optional[Dict[str, ModelProfile]] = None,
                 rtt: Optional[np.ndarray] = None):
        self.cfg = cfg or SimConfig()
        self.jobs = {j.job_id: j for j in jobs}
        self.policy = (make_policy(policy) if isinstance(policy, str)
                       else policy)
        self.profiles = profiles or default_profiles()
        self.rtt_s = validate_rtt(rtt if rtt is not None else DEFAULT_RTT)
        self.preempt_gain_s = self.cfg.preempt_gain_s
        self.preempt_cooldown_s = self.cfg.preempt_cooldown_s
        self.nodes: List[SimNode] = []
        nid = 0
        for c, n in enumerate(self.cfg.nodes_per_cluster):
            for _ in range(n):
                self.nodes.append(SimNode(nid, c, self.profiles,
                                          hbm=self.cfg.hbm,
                                          max_concurrency=self.cfg.max_concurrency))
                nid += 1
        self._set_deadlines(jobs)

        # state
        self.done: set = set()
        self.ready_at: Dict[int, float] = {}
        self.stage_wait: Dict[int, float] = {}
        self.stage_by_id: Dict[int, StageRecord] = {
            s.stage_id: s for j in jobs for s in j.stages}
        self.pending_deps: Dict[int, int] = {}
        self.job_done_stages: Dict[int, int] = {j.job_id: 0 for j in jobs}
        self.job_finish: Dict[int, float] = {}
        self.cold_starts = 0
        self.preemptions = 0
        self.waiting: List[Tuple[float, int, int]] = []   # priority heap
        self._views: Dict[int, SchedStage] = {
            s.stage_id: self._make_view(s) for j in jobs for s in j.stages}
        self.policy.setup(self)

    # --------------------------------------------------- Substrate protocol
    def node_ids(self) -> Sequence[int]:
        return range(len(self.nodes))

    def signal(self, node_id: int):
        return self.nodes[node_id].signal()

    def load(self, node_id: int) -> int:
        return len(self.nodes[node_id].running)

    def can_admit(self, node_id: int, r_need: float,
                  model: Optional[str] = None) -> bool:
        return self.nodes[node_id].can_admit(r_need, model)

    def t_act(self, node_id: int, model: str) -> float:
        return self.nodes[node_id].t_act(model)

    def degradation_cost(self, node_id: int,
                         r_need: float) -> Optional[float]:
        return self.nodes[node_id].degradation_cost(r_need)

    def known_stages(self) -> List[SchedStage]:
        return list(self._views.values())

    def static_reservation(self, stage: SchedStage) -> float:
        prof = self.profiles[stage.model]
        return prof.r_kv(stage.prompt_len, self.cfg.reserve_len)

    def t_exec_est(self, stage: SchedStage,
                   l_hat: Optional[float]) -> float:
        if l_hat is None:
            l_hat = float(self.stage_by_id[stage.stage_id].true_len)
        return self.profiles[stage.model].t_exec(stage.prompt_len, l_hat)

    def true_remaining_s(self, stage: SchedStage) -> float:
        job = self.jobs[stage.job_id]
        rem = 0.0
        for st in job.stages:
            if st.stage_id in self.done:
                continue
            prof = self.profiles[st.model]
            rem += prof.t_exec(st.obs.prompt_len, st.true_len)
        return rem

    def ready_since(self, stage_id: int) -> float:
        return self.ready_at.get(stage_id, float("inf"))

    def prefix_digests(self, stage) -> tuple:
        return ()   # trace stages carry no token-level prompts

    def _make_view(self, s: StageRecord) -> SchedStage:
        job = self.jobs[s.job_id]
        return SchedStage(stage_id=s.stage_id, job_id=s.job_id,
                          model=s.model, interactive=job.interactive,
                          prompt_len=s.obs.prompt_len,
                          arrival_s=job.arrival_s, deadline_s=job.deadline_s,
                          obs=s.obs)

    def view(self, stage_id: int) -> SchedStage:
        return self._views[stage_id]

    # ------------------------------------------------------------ deadlines
    def _isolated_time(self, job: JobRecord) -> float:
        """Critical-path exec time with everything warm (SLO profiling)."""
        finish: Dict[int, float] = {}
        for s in job.stages:
            prof = self.profiles[s.model]
            t = prof.t_exec(s.obs.prompt_len, s.true_len)
            start = max((finish[d] for d in s.deps), default=0.0)
            finish[s.stage_id] = start + t
        return max(finish.values())

    def _set_deadlines(self, jobs: Sequence[JobRecord]) -> None:
        per_app: Dict[str, List[float]] = {}
        iso: Dict[int, float] = {}
        for j in jobs:
            t = self._isolated_time(j)
            iso[j.job_id] = t
            per_app.setdefault(j.app, []).append(t)
        p50 = {a: float(np.median(v)) for a, v in per_app.items()}
        for j in jobs:
            j.deadline_s = self.cfg.slo_factor * max(p50[j.app], iso[j.job_id])

    # ------------------------------------------------------------ event loop
    def run(self, horizon_s: float = float("inf")) -> SimResult:
        events: List[Tuple[float, int, str, tuple]] = []
        seq = 0

        def push(t, kind, *args):
            nonlocal seq
            seq += 1
            heapq.heappush(events, (t, seq, kind, args))

        self._push = push
        for j in self.jobs.values():
            push(j.arrival_s, "arrival", j.job_id)

        while events:
            now, _, kind, args = heapq.heappop(events)
            if now > horizon_s:
                break
            if kind == "arrival":
                job = self.jobs[args[0]]
                for s in job.stages:
                    self.pending_deps[s.stage_id] = len(s.deps)
                for s in job.stages:
                    if not s.deps:
                        self._mark_ready(s, now)
            elif kind == "finish":
                node_id, stage_id = args
                node = self.nodes[node_id]
                node.finish(stage_id)
                s = self.stage_by_id[stage_id]
                self.done.add(stage_id)
                job = self.jobs[s.job_id]
                self.job_done_stages[s.job_id] += 1
                if self.job_done_stages[s.job_id] == len(job.stages):
                    self.job_finish[s.job_id] = now
                prof = self.profiles[s.model]
                actual_kv = prof.r_kv(s.obs.prompt_len, s.true_len)
                rem = sum(
                    self.profiles[st.model].t_exec(st.obs.prompt_len,
                                                   st.true_len)
                    for st in job.stages if st.stage_id not in self.done)
                self.policy.on_finish(self, self.view(stage_id), actual_kv,
                                      rem)
                # successors
                succs = [st for st in job.stages
                         if s.stage_id in st.deps]
                for st in succs:
                    self.pending_deps[st.stage_id] -= 1
                    if self.pending_deps[st.stage_id] == 0:
                        if (not self.policy.requeue_at_boundary
                                and self._try_start(st, node, now)):
                            continue  # job continuity: bypass the queue
                        self._mark_ready(st, now)
            self._dispatch(now)
        return self._metrics()

    def _mark_ready(self, s: StageRecord, now: float) -> None:
        self.ready_at[s.stage_id] = now
        pri = self.policy.priority(self, self.view(s.stage_id), now)
        heapq.heappush(self.waiting, (pri, s.stage_id, 0))

    def _try_start(self, s: StageRecord, node: SimNode, now: float) -> bool:
        r_need = self.policy.reservation(self, self.view(s.stage_id))
        if not node.can_admit(r_need, s.model):
            return False
        return self._start_on(s, node, now, r_need)

    def _start_on(self, s: StageRecord, node: SimNode, now: float,
                  r_need: float) -> bool:
        prof = self.profiles[s.model]
        t_act = node.activate(s.model)
        if not node.acc.can_admit(r_need):
            node.make_room(r_need)   # degradation levels 1-2
        if t_act == float("inf") or not node.acc.can_admit(r_need):
            # genuinely infeasible right now: requeue
            heapq.heappush(
                self.waiting,
                (self.policy.priority(self, self.view(s.stage_id), now),
                 s.stage_id, 0))
            return False
        if t_act > 0.01:
            self.cold_starts += 1
        rtt = float(self.rtt_s[s.obs.src_cluster, node.cluster_id])
        dur = prof.t_exec(s.obs.prompt_len, s.true_len)
        finish_at = now + rtt + t_act + dur
        enq = self.ready_at.get(s.stage_id, now)
        self.stage_wait[s.stage_id] = max(0.0, now - enq) + rtt + t_act
        node.start(s.stage_id, s.model, r_need, finish_at, now, enq)
        self._push(finish_at, "finish", node.node_id, s.stage_id)
        return True

    def _dispatch(self, now: float) -> None:
        retry: List[Tuple[float, int, int]] = []
        while self.waiting:
            pri, stage_id, _ = heapq.heappop(self.waiting)
            if stage_id in self.done:
                continue
            s = self.stage_by_id[stage_id]
            view = self.view(stage_id)
            r_need = self.policy.reservation(self, view)
            nid = self.policy.route(self, view, r_need)
            if nid is None:
                retry.append((pri, stage_id, 0))
                # head-of-line: policies block behind their head unless a
                # different-class stage could fit elsewhere
                break
            if not self._start_on(s, self.nodes[nid], now, r_need):
                break  # post-activation admission failed; stage was requeued
        for e in retry:
            heapq.heappush(self.waiting, e)

    # -------------------------------------------------------------- metrics
    def _metrics(self) -> SimResult:
        lat, slo_ok, int_delays = [], [], []
        for j in self.jobs.values():
            if j.job_id not in self.job_finish:
                slo_ok.append(False)
                continue
            l = self.job_finish[j.job_id] - j.arrival_s
            lat.append(l)
            waits = sum(self.stage_wait.get(s.stage_id, 0.0)
                        for s in j.stages)
            if j.interactive:
                int_delays.append(waits)
                slo_ok.append(waits <= self.cfg.interactive_wait_budget_s)
            else:
                slo_ok.append(l <= j.deadline_s)
        return SimResult(
            policy=self.policy.name,
            slo_attainment=float(np.mean(slo_ok)) if slo_ok else 0.0,
            mean_latency_s=float(np.mean(lat)) if lat else float("inf"),
            interactive_queue_delay_s=(float(np.mean(int_delays))
                                       if int_delays else 0.0),
            p95_latency_s=float(np.percentile(lat, 95)) if lat else float("inf"),
            finished_jobs=len(self.job_finish),
            cold_starts=self.cold_starts,
            preemptions=self.preemptions)
