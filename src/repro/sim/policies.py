"""Scheduling policies for the trace-driven simulator (§IV.A baselines).

All policies share the same node runtime (residency, accounting, profiles),
dynamic arrivals and SLOs — they differ ONLY in admission, routing and queue
ordering, mirroring the paper's controlled comparison:

  fcfs          — global FIFO, least-loaded feasible node
  edf           — deadline-first for batch, class-priority for interactive
  oracle-srtf   — shortest TRUE remaining time (perfect knowledge upper bound)
  maestro       — predicted remaining time (Eq. 7-8) + fitness routing
                  (Eq. 5, Alg. 3) + rho-margin admission + boundary preemption
  maestro-np    — maestro without boundary preemption (Table II)
Routing-only variants for Table VIII: baseline-lb, binpack (gamma=0),
maestro-aff (gamma=0.25).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, TYPE_CHECKING

import numpy as np

from repro.core.control_loop import MaestroController
from repro.core.predictor.length_model import MaestroPred
from repro.core.sched.fitness import StageRequest
from repro.core.sched.srtf import state_key
from repro.data.tracegen import StageRecord

if TYPE_CHECKING:
    from repro.sim.simulator import Simulator


class Policy:
    name = "base"
    requeue_at_boundary = True     # boundary preemption semantics

    def bind(self, sim: "Simulator") -> None:
        self.sim = sim

    def priority(self, s: StageRecord, now: float) -> float:
        raise NotImplementedError

    def reservation(self, s: StageRecord) -> float:
        """KV bytes reserved at admission."""
        prof = self.sim.profiles[s.model]
        return prof.r_kv(s.obs.prompt_len, self.sim.cfg.reserve_len)

    def route(self, s: StageRecord, r_need: float) -> Optional[int]:
        """Least-loaded feasible node (baseline routing)."""
        best, load = None, float("inf")
        for n in self.sim.nodes:
            if n.can_admit(r_need, s.model):
                l = len(n.running)
                if l < load:
                    best, load = n.node_id, l
        return best

    def on_finish(self, s: StageRecord, actual_kv: float,
                  job_remaining_s: float) -> None:
        pass


class FCFS(Policy):
    name = "fcfs"
    requeue_at_boundary = False

    def priority(self, s, now):
        return float(s.stage_id)


class EDF(Policy):
    name = "edf"
    requeue_at_boundary = False

    def priority(self, s, now):
        job = self.sim.jobs[s.job_id]
        if job.interactive:
            return -1e9 + job.arrival_s     # class priority for interactive
        return job.arrival_s + job.deadline_s


class OracleSRTF(Policy):
    name = "oracle-srtf"

    def priority(self, s, now):
        job = self.sim.jobs[s.job_id]
        rem = 0.0
        for st in job.stages:
            if st.stage_id in self.sim.done:
                continue
            prof = self.sim.profiles[st.model]
            rem += prof.t_exec(st.obs.prompt_len, st.true_len)
        return rem - (1e9 if job.interactive else 0.0)


class Maestro(Policy):
    name = "maestro"

    def __init__(self, predictor: MaestroPred, gamma: float = 0.25,
                 preempt: bool = True):
        self.predictor = predictor
        self.gamma = gamma
        self.requeue_at_boundary = preempt
        self._cache: Dict[int, Dict[str, float]] = {}

    def bind(self, sim):
        super().bind(sim)
        self.ctl = MaestroController(self.predictor, sim.profiles,
                                     sim.rtt, gamma=self.gamma)
        # batch-precompute per-stage predictions (same inputs the dispatch
        # gateway would see at stage creation; batching is just speed)
        stages = list(sim.stage_by_id.values())
        out = self.predictor.predict(list(s.obs for s in stages))
        for s, L, pt in zip(stages, out["length"], out["p_tool"]):
            prof = sim.profiles[s.model]
            self._cache[s.stage_id] = {
                "length": float(L), "p_tool": float(pt),
                "t_exec": prof.t_exec(s.obs.prompt_len, float(L)),
                "r_kv": prof.r_kv(s.obs.prompt_len, float(L))}

    def _pred(self, s: StageRecord) -> Dict[str, float]:
        return self._cache[s.stage_id]

    def priority(self, s, now):
        p = self._pred(s)
        key = state_key(s.obs.app, s.obs.role, s.obs.invocation_idx,
                        p["p_tool"])
        t_rem = p["t_exec"] + self.ctl.wf_profiles.future_median(key)
        # aging prevents starvation of long batch jobs
        wait = max(0.0, now - self.sim.ready_at.get(s.stage_id, now))
        t_rem -= self.ctl.queue.aging * wait
        return t_rem - (1e9 if self.sim.jobs[s.job_id].interactive else 0.0)

    def reservation(self, s):
        p = self._pred(s)
        return self.ctl.rho.r_need(p["r_kv"])

    def route(self, s, r_need):
        req = StageRequest(
            stage_id=s.stage_id, model=s.model, r_need=r_need,
            interactive=self.sim.jobs[s.job_id].interactive,
            src_cluster=s.obs.src_cluster, t_exec=self._pred(s)["t_exec"])
        # feasibility filter FIRST (Alg. 3 line 3), then rank by S(N,T);
        # C_deg enters the ranking via the activation path's implicit
        # evictions (residency LRU = degradation levels 1-2)
        nodes = [n.signal() for n in self.sim.nodes
                 if n.can_admit(r_need, s.model)]
        if not nodes:
            return None
        sel = self.ctl.router.select(
            req, nodes,
            t_act_of=lambda sig, m: self.sim.nodes[sig.node_id].t_act(m),
            c_deg_of=lambda sig, rq: self.sim.nodes[sig.node_id]
                .degradation_cost(rq.r_need))
        if sel is None:
            return None
        return sel[0].node_id

    def on_finish(self, s, actual_kv, job_remaining_s):
        p = self._pred(s)
        self.ctl.rho.observe(actual_kv, max(p["r_kv"], 1.0))
        key = state_key(s.obs.app, s.obs.role, s.obs.invocation_idx,
                        p["p_tool"])
        self.ctl.wf_profiles.record(key, job_remaining_s)


class MaestroNoPreempt(Maestro):
    name = "maestro-np"

    def __init__(self, predictor, gamma: float = 0.25):
        super().__init__(predictor, gamma=gamma, preempt=False)


class BaselineLB(Maestro):
    """Table VIII 'Baseline': load balancing, no prediction-guided packing."""
    name = "baseline-lb"

    def route(self, s, r_need):
        return Policy.route(self, s, r_need)

    def reservation(self, s):
        return Policy.reservation(self, s)


class BinPackOnly(Maestro):
    """Table VIII 'BinPack Only': KV-aware packing, network-blind (gamma=0)."""
    name = "binpack"

    def __init__(self, predictor):
        super().__init__(predictor, gamma=0.0)
