"""Simulated node/cluster state: each node owns a hierarchical residency
manager, a memory accountant and an elastic KV pool — the same core objects
the real serving runtime uses, driven by simulated time."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.predictor.cost_model import HardwareSpec, ModelProfile
from repro.core.runtime.accounting import MemoryAccountant
from repro.core.runtime.coordination import (EngineInfo, EngineState,
                                             plan_degradation)
from repro.core.runtime.residency import HierarchicalResidency, ModelState
from repro.core.sched.fitness import NodeSignal


@dataclasses.dataclass
class RunningStage:
    stage_id: int
    model: str
    kv_reserved: float
    finish_at: float


class SimNode:
    def __init__(self, node_id: int, cluster_id: int,
                 profiles: Dict[str, ModelProfile],
                 hbm: float = 40e9, max_concurrency: int = 8,
                 hw: Optional[HardwareSpec] = None,
                 host_ram: float = 256e9, disk: float = 2e12):
        self.node_id = node_id
        self.cluster_id = cluster_id
        self.profiles = profiles
        self.hw = hw or HardwareSpec()
        self.residency = HierarchicalResidency(
            profiles, c_gpu=hbm * 0.9, c_cpu=host_ram, c_disk=disk, hw=self.hw)
        self.acc = MemoryAccountant(m_total=hbm, m_other=1e9)
        self.max_concurrency = max_concurrency
        self.running: Dict[int, RunningStage] = {}
        self.queue_delay_ewma = 0.0
        self.busy_until = 0.0

    # ----------------------------------------------------------- signals
    def signal(self) -> NodeSignal:
        warm = {}
        for m in self.residency.warm_set():
            warm[m] = self.residency.activation_latency(m)
        return NodeSignal(node_id=self.node_id, cluster_id=self.cluster_id,
                          headroom=self.acc.headroom,
                          queue_delay_s=self.queue_delay_ewma,
                          warm_models=warm, total_hbm=self.acc.m_total)

    def t_act(self, model: str) -> float:
        return self.residency.activation_latency(model)

    def has_slot(self) -> bool:
        return len(self.running) < self.max_concurrency

    def activation_delta(self, model: str) -> float:
        """Extra M_res bytes that activating `model` would add."""
        prof = self.profiles[model]
        st = self.residency.state[model]
        delta = 0.0
        if model not in self.acc.weights:
            delta += prof.weight_bytes
        if model not in self.acc.ctx:
            delta += prof.ctx_bytes
        return delta

    def can_admit(self, r_need: float, model: Optional[str] = None) -> bool:
        if not self.has_slot():
            return False
        extra = self.activation_delta(model) if model else 0.0
        if self.acc.can_admit(r_need + extra):
            return True
        if model is None:
            return False
        # eviction-aware feasibility (degradation levels 1-2 are available to
        # the activation path): everything except in-flight models' weights
        # and contexts can be reclaimed
        active = {r.model for r in self.running.values()} | {model}
        floor = sum(self.profiles[m].weight_bytes + self.profiles[m].ctx_bytes
                    for m in active)
        return (floor + self.acc.m_kv + self.acc.m_other + r_need
                <= self.acc.m_total)

    def degradation_cost(self, r_need: float) -> Optional[float]:
        """C_deg for admitting r_need via Algorithm 2 (None = impossible)."""
        shortfall = r_need - self.acc.headroom
        if shortfall <= 0:
            return 0.0
        engines = []
        for m in self.residency.warm_set():
            st = self.residency.state[m]
            active = any(r.model == m for r in self.running.values())
            kv = sum(r.kv_reserved for r in self.running.values()
                     if r.model == m)
            engines.append(EngineInfo(
                model=m,
                state=(EngineState.ACTIVE if active else
                       EngineState.IDLE if st is ModelState.RUNNING
                       else EngineState.SLEEPING),
                weight_bytes=self.profiles[m].weight_bytes,
                ctx_bytes=self.profiles[m].ctx_bytes,
                kv_bytes=kv,
                kv_tokens=int(kv / max(
                    self.profiles[m].alpha_bytes_per_token, 1)),
                decode_tok_per_s=1.0 / self.profiles[m].t_decode))
        plan = plan_degradation(shortfall, engines, self.hw)
        return None if plan is None else plan.c_deg

    # ----------------------------------------------------------- execution
    def activate(self, model: str) -> float:
        """Ensure weights on device; returns activation seconds. Updates the
        accountant's weight/context registry to mirror residency state."""
        self.residency.pinned = {r.model for r in self.running.values()}
        ok, t_act = self.residency.ensure_gpu(model)
        if not ok:
            return float("inf")
        self._sync_accounting()
        return t_act

    def make_room(self, r_need: float) -> None:
        """Degradation levels 1-2: sleep idle models, then drop sleeping
        contexts, until r_need fits (Algorithm 2's cheap prefix)."""
        active = {r.model for r in self.running.values()}
        for m in list(self.residency.lru["gpu"]):
            if self.acc.can_admit(r_need):
                return
            if m not in active:
                self.residency.sleep(m)               # level 1
                self._sync_accounting()
        for m, st in list(self.residency.state.items()):
            if self.acc.can_admit(r_need):
                return
            if m not in active and st is ModelState.SLEEPING:
                self.residency.demote_context(m)      # level 2
                self._sync_accounting()

    def _sync_accounting(self) -> None:
        self.acc.weights.clear()
        self.acc.ctx.clear()
        for m, st in self.residency.state.items():
            if st is ModelState.RUNNING:
                self.acc.register_weights(m, self.profiles[m].weight_bytes)
                self.acc.register_context(m, self.profiles[m].ctx_bytes)
            elif st is ModelState.SLEEPING:
                self.acc.register_context(m, self.profiles[m].ctx_bytes)

    def start(self, stage_id: int, model: str, kv: float, finish_at: float,
              now: float, enqueue_t: float) -> None:
        self.acc.admit_kv(kv)
        self.running[stage_id] = RunningStage(stage_id, model, kv, finish_at)
        wait = max(0.0, now - enqueue_t)
        self.queue_delay_ewma = 0.8 * self.queue_delay_ewma + 0.2 * wait

    def finish(self, stage_id: int) -> None:
        r = self.running.pop(stage_id, None)
        if r is not None:
            self.acc.release_kv(r.kv_reserved)
