#!/usr/bin/env python
"""CI docs check: every file under docs/ must be REACHABLE from the README.

The README is the repo's front door; a doc nobody links is a doc nobody
finds. Reachability is transitive: a file linked from a doc that is itself
reachable counts (so docs/ can grow sub-pages and figures without forcing
a README link for each). A link counts when the target's repo-relative
path, or its path relative to the linking document's directory, appears in
the document text. Fails (exit 1) listing any unreachable docs/ file.
"""
from __future__ import annotations

import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _text(path: pathlib.Path) -> str:
    try:
        return path.read_text(encoding="utf-8")
    except (UnicodeDecodeError, OSError):
        return ""            # binary assets link TO nothing


def main() -> int:
    docs = sorted(p for p in (ROOT / "docs").rglob("*") if p.is_file())
    if not docs:
        print("check_docs_links: no files under docs/ — nothing to check")
        return 0
    # BFS from README.md: each newly reached doc's text can link further
    sources = [(ROOT, _text(ROOT / "README.md"))]
    unreached = set(docs)
    progress = True
    while progress and unreached:
        progress = False
        for p in sorted(unreached):
            rel_repo = str(p.relative_to(ROOT))
            if any(rel_repo in text
                   or os.path.relpath(p, src_dir) in text
                   for src_dir, text in sources):
                unreached.discard(p)
                sources.append((p.parent, _text(p)))
                progress = True
    if unreached:
        print("check_docs_links: files under docs/ not reachable from "
              "README.md:")
        for p in sorted(unreached):
            print(f"  - {p.relative_to(ROOT)}")
        return 1
    print(f"check_docs_links: OK ({len(docs)} docs file(s) all reachable "
          "from README.md)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
