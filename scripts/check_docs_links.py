#!/usr/bin/env python
"""CI docs check, two layers:

1. REACHABILITY — every file under docs/ must be reachable from README.md.
   The README is the repo's front door; a doc nobody links is a doc nobody
   finds. Reachability is transitive: a file linked from a doc that is
   itself reachable counts (so docs/ can grow sub-pages and figures without
   forcing a README link for each). A link counts when the target's
   repo-relative path, or its path relative to the linking document's
   directory, appears in the document text.

2. LINK VALIDITY — every RELATIVE markdown link in README.md and docs/*.md
   must resolve: the target file exists, and when the link carries a
   ``#fragment`` pointing into a markdown file, a heading with that
   GitHub-style anchor slug exists in the target (``#fragment`` alone
   checks the linking document itself). External schemes (http/https/
   mailto) are not validated.

Fails (exit 1) listing any unreachable docs/ file or broken link/anchor.
"""
from __future__ import annotations

import os
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# [text](target) — target up to the first closing paren/whitespace; images
# and reference-style definitions are out of scope for this repo's docs
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _text(path: pathlib.Path) -> str:
    try:
        return path.read_text(encoding="utf-8")
    except (UnicodeDecodeError, OSError):
        return ""            # binary assets link TO nothing


def _anchor_slug(heading: str) -> str:
    """GitHub-style anchor for a markdown heading: strip inline code/link
    markup, lowercase, drop everything but word chars/spaces/hyphens, then
    spaces -> hyphens."""
    h = re.sub(r"`([^`]*)`", r"\1", heading)          # inline code
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)    # inline links
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(md: pathlib.Path) -> set:
    return {_anchor_slug(m.group(1))
            for m in _HEADING_RE.finditer(_text(md))}


def check_relative_links(md_files) -> list:
    """Validate every relative link (and #anchor) in the given markdown
    files; returns a list of human-readable error strings."""
    errors = []
    for doc in md_files:
        rel_doc = doc.relative_to(ROOT)
        for target in _LINK_RE.findall(_text(doc)):
            if target.startswith(_EXTERNAL):
                continue
            path_part, _, fragment = target.partition("#")
            tgt = doc if not path_part \
                else (doc.parent / path_part).resolve()
            if path_part and not tgt.exists():
                errors.append(f"{rel_doc}: broken link -> {target}")
                continue
            if fragment and tgt.suffix == ".md":
                if fragment not in _anchors(tgt):
                    errors.append(f"{rel_doc}: missing anchor "
                                  f"#{fragment} in {tgt.relative_to(ROOT)}")
    return errors


def main() -> int:
    docs = sorted(p for p in (ROOT / "docs").rglob("*") if p.is_file())
    if not docs:
        print("check_docs_links: no files under docs/ — nothing to check")
        return 0
    # 1) BFS from README.md: each newly reached doc's text can link further
    sources = [(ROOT, _text(ROOT / "README.md"))]
    unreached = set(docs)
    progress = True
    while progress and unreached:
        progress = False
        for p in sorted(unreached):
            rel_repo = str(p.relative_to(ROOT))
            if any(rel_repo in text
                   or os.path.relpath(p, src_dir) in text
                   for src_dir, text in sources):
                unreached.discard(p)
                sources.append((p.parent, _text(p)))
                progress = True
    failed = False
    if unreached:
        failed = True
        print("check_docs_links: files under docs/ not reachable from "
              "README.md:")
        for p in sorted(unreached):
            print(f"  - {p.relative_to(ROOT)}")
    # 2) relative links + anchors in README and every markdown doc
    md_files = [ROOT / "README.md"] + [p for p in docs
                                       if p.suffix == ".md"]
    errors = check_relative_links(md_files)
    if errors:
        failed = True
        print("check_docs_links: broken relative links/anchors:")
        for e in errors:
            print(f"  - {e}")
    if failed:
        return 1
    print(f"check_docs_links: OK ({len(docs)} docs file(s) reachable, "
          f"{len(md_files)} markdown file(s) link/anchor-clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
