"""Inject rendered roofline tables into EXPERIMENTS.md placeholders."""
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
from repro.launch.report import table  # noqa: E402

DOC = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"

MARKERS = {
    "<!-- BASELINE_TABLE -->": ("single", "tp_sp"),
    "<!-- OPTIMIZED_TABLE -->": ("single", "fsdp_cp"),
    "<!-- MULTIPOD_TABLE -->": ("multi", "tp_sp"),
}


def main():
    text = DOC.read_text()
    for marker, (mesh, mode) in MARKERS.items():
        block = f"{marker}\n{table(mesh, mode)}"
        if marker in text:
            text = text.replace(marker, block)
        else:
            # refresh: replace marker + following table lines
            pat = re.compile(re.escape(marker) + r"(\n\|[^\n]*)*")
            text = pat.sub(lambda _: block, text)
    DOC.write_text(text)
    print("tables injected")


if __name__ == "__main__":
    main()
